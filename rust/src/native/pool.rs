//! A persistent worker pool — `std::thread` only, no rayon.
//!
//! Every native kernel is embarrassingly parallel across the folded
//! batch×heads (`BH`) dimension (and, for the chunkwise form, across
//! `(bh, chunk)` tiles once the per-chunk states are materialized). The pool
//! turns that structure into wall-clock speedup with three primitives:
//!
//! - [`ThreadPool::run`] — indexed tasks drained from a shared atomic counter;
//! - [`ThreadPool::run_chunks`] / [`ThreadPool::run_chunks3`] — safe
//!   fixed-stride windows of one (or three) output buffers;
//! - [`ThreadPool::run_stripes`] — contiguous row-block partition for the
//!   dense GEMM wrappers.
//!
//! Workers are spawned **once**, at pool construction, and live for the
//! pool's lifetime; each submission publishes one job (an erased
//! `Fn(usize)`) that workers and the submitting thread drain together from
//! an atomic counter. Amortizing thread creation matters for the LM training
//! loop, which issues hundreds of small GEMMs per optimizer step — at ~10 µs
//! per `std::thread::spawn`, the old scoped-spawn-per-call design spent more
//! time creating threads than multiplying matrices on the tiny presets. A
//! submission is now one mutex hand-off plus a condvar wake (~1 µs).
//!
//! Task decomposition is *independent of the worker count*: task `i` always
//! performs the same arithmetic, so kernel results do not depend on
//! `RUST_PALLAS_THREADS` — bitwise on the default build; within last-bit FMA
//! rounding under `--features simd`, where stripe boundaries move rows
//! between the fused and scalar tile paths (the invariance test pins 1e-5).
//!
//! Nested submissions (a task body calling back into a pool) execute inline
//! on the calling worker: the pool runs one job at a time, so re-entering
//! from inside a task would otherwise deadlock. No native kernel nests
//! today — the guard keeps composition safe as callers evolve.
//!
//! ## Verification
//!
//! The job-completion protocol is machine-checked three ways (see the
//! "Verification" section of `rust/README.md`):
//!
//! - **Loom** (`--features loom`, needs the commented-out `loom`
//!   dev-dependency): every synchronization primitive below resolves through
//!   the [`sync`] shim to `loom::sync`/`loom::thread`, and
//!   `tests/loom_pool.rs` exhaustively explores the submit/drain/completion
//!   interleavings, including the weak-memory reorderings the orderings
//!   documented inline must survive.
//! - **Always-on protocol model**: `tests/pool_model.rs` re-states the
//!   claim/countdown protocol as a [`crate::util::modelcheck`] model and
//!   explores *all* sequentially-consistent interleavings on every
//!   `cargo test` run — no lost or double-claimed tasks, no deadlock, panic
//!   payloads always delivered.
//! - **ThreadSanitizer / Miri CI lanes** run the real pool under the
//!   `native_parallel`/`optimizer`/`infer` suites.

use std::cell::Cell;
use std::marker::PhantomData;

use sync::atomic::{AtomicUsize, Ordering};
use sync::{Arc, Condvar, Mutex};

#[cfg(not(feature = "loom"))]
use std::sync::OnceLock;

/// Synchronization shim: `loom`'s model-checked primitives under
/// `--features loom`, the real `std` ones otherwise. Everything the pool
/// synchronizes through **must** come from here so the loom models exercise
/// the exact shipped protocol.
pub(crate) mod sync {
    #[cfg(not(feature = "loom"))]
    pub(crate) use std::sync::{atomic, Arc, Condvar, Mutex};
    #[cfg(not(feature = "loom"))]
    pub(crate) use std::thread;

    #[cfg(feature = "loom")]
    pub(crate) use loom::sync::{atomic, Arc, Condvar, Mutex};
    #[cfg(feature = "loom")]
    pub(crate) use loom::thread;
}

#[cfg(not(feature = "loom"))]
thread_local! {
    /// Set while a pool worker (or a submitter draining its own job) is
    /// inside a task body — nested `run` calls detect it and go inline.
    static IN_POOL_TASK: Cell<bool> = const { Cell::new(false) };
}

#[cfg(feature = "loom")]
loom::thread_local! {
    /// Loom-modeled twin of the `std` declaration above.
    static IN_POOL_TASK: Cell<bool> = Cell::new(false);
}

/// Type-erased pointer to the submission's `Fn(usize)`. Valid for the
/// duration of the owning [`ThreadPool::run`] call: `run` does not return
/// until every claimed task has finished, and tasks are only claimed while
/// unfinished work remains.
struct RawTask(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (bound enforced at submission) and the
// pointer is only dereferenced between job publication and completion, while
// the submitter keeps the closure alive on its stack.
unsafe impl Send for RawTask {}
unsafe impl Sync for RawTask {}

/// One published submission: the erased task body plus its claim/completion
/// counters. Workers hold jobs via `Arc`, so a late-waking worker can never
/// confuse an old job's closure with a new job's counters.
struct Job {
    f: RawTask,
    tasks: usize,
    next: AtomicUsize,
    pending: AtomicUsize,
    /// First task panic, carried back to the submitter (the scoped-spawn
    /// predecessor propagated panics at scope exit; a hang would be worse).
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Job {
    /// Claim-and-run until the task counter is exhausted. The last finisher
    /// wakes the submitter.
    fn drain(&self, core: &Core) {
        loop {
            // ordering: Relaxed is sufficient for `next` (loom-modeled)
            // because a fetch_add's read-modify-write atomicity alone
            // guarantees each index is claimed at most once, and the claim
            // itself carries no data — the closure pointer was published to
            // this thread under the `state` mutex (a happens-before edge at
            // job pickup), and task *results* travel through `pending`'s
            // AcqRel/Acquire pair below, never through `next`.
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.tasks {
                return;
            }
            // SAFETY: claimed index < tasks, so the submitter is still
            // blocked in `run` and the closure is alive.
            let result =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
                    (*self.f.0)(i)
                }));
            if let Err(payload) = result {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            // ordering: `AcqRel` is load-bearing — the Release half publishes this
            // task's buffer writes into `pending`'s modification order, and
            // because every decrement is a read-modify-write, the chain of
            // fetch_subs forms one release sequence — the submitter's single
            // Acquire load of 0 therefore synchronizes with *every* finished
            // task, not just the last one. (Relaxed here is the canonical
            // bug the loom lane exists to catch.)
            if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                // lock-then-notify pairs with the submitter's wait loop: the
                // submitter only blocks while holding `state`, so the wake
                // cannot slip between its pending check and the wait
                let _guard = core.state.lock().unwrap();
                core.done_cv.notify_all();
            }
        }
    }
}

/// Publication slot shared between submitters and workers.
struct Slot {
    job: Option<Arc<Job>>,
    epoch: u64,
    shutdown: bool,
}

struct Core {
    threads: usize,
    state: Mutex<Slot>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Serializes submissions: the pool runs one job at a time.
    submit: Mutex<()>,
}

impl Core {
    fn worker(self: Arc<Self>) {
        IN_POOL_TASK.with(|f| f.set(true));
        let mut seen = 0u64;
        loop {
            let job = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if st.epoch != seen {
                        seen = st.epoch;
                        if let Some(j) = st.job.clone() {
                            break j;
                        }
                    }
                    st = self.work_cv.wait(st).unwrap();
                }
            };
            job.drain(&self);
        }
    }
}

/// Owns the worker threads; dropped when the last [`ThreadPool`] clone goes
/// away (workers hold only the [`Core`], so there is no keep-alive cycle).
struct PoolOwner {
    core: Arc<Core>,
    handles: Mutex<Vec<sync::thread::JoinHandle<()>>>,
}

impl Drop for PoolOwner {
    fn drop(&mut self) {
        {
            let mut st = self.core.state.lock().unwrap();
            st.shutdown = true;
            self.core.work_cv.notify_all();
        }
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Cheap-to-clone handle to one persistent worker pool.
#[derive(Clone)]
pub struct ThreadPool {
    inner: Arc<PoolOwner>,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("threads", &self.threads()).finish()
    }
}

impl ThreadPool {
    /// Pool with an explicit worker count (clamped to ≥ 1). Spawns
    /// `threads - 1` persistent workers — the submitting thread is the
    /// remaining executor, so a 1-thread pool runs everything inline.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let core = Arc::new(Core {
            threads,
            state: Mutex::new(Slot { job: None, epoch: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            submit: Mutex::new(()),
        });
        let handles = (1..threads)
            .map(|_| {
                let core = core.clone();
                sync::thread::spawn(move || core.worker())
            })
            .collect();
        Self { inner: Arc::new(PoolOwner { core, handles: Mutex::new(handles) }) }
    }

    /// Pool sized from `RUST_PALLAS_THREADS`; `0`, unset, or unparseable
    /// means auto-detect ([`std::thread::available_parallelism`]).
    pub fn from_env() -> Self {
        Self::new(Self::env_threads())
    }

    /// The worker count [`from_env`](Self::from_env) would use, without
    /// spawning anything — for callers that only need the number.
    pub fn env_threads() -> usize {
        let n = std::env::var("RUST_PALLAS_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .unwrap_or(0);
        if n == 0 {
            Self::available()
        } else {
            n
        }
    }

    /// Host parallelism (1 if undetectable).
    pub fn available() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// The process-wide pool, sized once from the environment. (Not
    /// available under the loom model build: loom threads only exist inside
    /// a `loom::model` run, so a `'static` pool cannot outlive one.)
    #[cfg(not(feature = "loom"))]
    pub fn global() -> &'static ThreadPool {
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(ThreadPool::from_env)
    }

    pub fn threads(&self) -> usize {
        self.inner.core.threads
    }

    /// Run `f(0) … f(tasks-1)`, drained from a shared counter across the
    /// pool. Tasks must touch disjoint data (or only `&` data). Runs inline
    /// when the pool is size 1, the job is a single task, or the caller is
    /// itself a pool task (nested submission).
    pub fn run<F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let workers = self.threads().min(tasks);
        if workers <= 1 || IN_POOL_TASK.with(|t| t.get()) {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        let core = &self.inner.core;
        let _submission = core.submit.lock().unwrap();
        let erased: &(dyn Fn(usize) + Sync) = &f;
        let job = Arc::new(Job {
            f: RawTask(erased as *const _),
            tasks,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(tasks),
            panic: Mutex::new(None),
        });
        {
            let mut st = core.state.lock().unwrap();
            st.job = Some(job.clone());
            st.epoch += 1;
            core.work_cv.notify_all();
        }
        // the submitter is a full participant (and flags itself so that a
        // nested submission from inside `f` goes inline instead of
        // re-entering the single-job pool)
        IN_POOL_TASK.with(|t| t.set(true));
        job.drain(core);
        IN_POOL_TASK.with(|t| t.set(false));
        let mut st = core.state.lock().unwrap();
        // ordering: Acquire pairs with every worker's AcqRel fetch_sub above — observing
        // 0 synchronizes with the whole decrement chain, so all task writes
        // are visible before `run` returns — which is why callers (and the
        // unit tests below) may read task outputs with plain loads afterwards.
        while job.pending.load(Ordering::Acquire) > 0 {
            st = core.done_cv.wait(st).unwrap();
        }
        st.job = None;
        drop(st);
        if let Some(payload) = job.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }

    /// Split `buf` into `buf.len() / chunk` consecutive windows of `chunk`
    /// elements and run `f(window_index, window)` for each, in parallel.
    /// `buf.len()` must be a multiple of `chunk`.
    pub fn run_chunks<F>(&self, buf: &mut [f32], chunk: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        if buf.is_empty() {
            return;
        }
        debug_assert!(chunk > 0 && buf.len() % chunk == 0);
        let tasks = buf.len() / chunk;
        let parts = SliceParts::new(buf);
        self.run(tasks, |i| {
            // SAFETY: one window per task index — disjoint by construction.
            let w = unsafe { parts.window(i * chunk, chunk) };
            f(i, w);
        });
    }

    /// Three-buffer variant of [`run_chunks`](Self::run_chunks): window `i`
    /// of each buffer is handed to the same task (the kernel backward passes
    /// write `dq`/`dk`/`dv` for one `bh` slice together).
    #[allow(clippy::too_many_arguments)]
    pub fn run_chunks3<F>(
        &self,
        a: &mut [f32],
        ca: usize,
        b: &mut [f32],
        cb: usize,
        c: &mut [f32],
        cc: usize,
        f: F,
    ) where
        F: Fn(usize, &mut [f32], &mut [f32], &mut [f32]) + Sync,
    {
        if a.is_empty() && b.is_empty() && c.is_empty() {
            return;
        }
        // hard asserts: a silent length mismatch would skip trailing windows
        assert!(ca > 0 && cb > 0 && cc > 0, "run_chunks3: zero stride");
        let tasks = a.len() / ca;
        assert!(
            a.len() == tasks * ca && b.len() == tasks * cb && c.len() == tasks * cc,
            "run_chunks3: buffers disagree on task count ({} / {} / {} windows)",
            a.len() / ca,
            b.len() / cb,
            c.len() / cc,
        );
        let (pa, pb, pc) = (SliceParts::new(a), SliceParts::new(b), SliceParts::new(c));
        self.run(tasks, |i| {
            // SAFETY: one window of each buffer per task index — disjoint.
            let (wa, wb, wc) = unsafe {
                (pa.window(i * ca, ca), pb.window(i * cb, cb), pc.window(i * cc, cc))
            };
            f(i, wa, wb, wc);
        });
    }

    /// Partition `buf` (rows of `row` elements) into at most `threads`
    /// contiguous row stripes and run `f(first_row, stripe)` per stripe —
    /// the row-parallel GEMM entry point.
    pub fn run_stripes<F>(&self, buf: &mut [f32], row: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        if buf.is_empty() {
            return;
        }
        debug_assert!(row > 0 && buf.len() % row == 0);
        let rows = buf.len() / row;
        let workers = self.threads().min(rows);
        if workers <= 1 {
            f(0, buf);
            return;
        }
        let per = rows.div_ceil(workers);
        let stripes = rows.div_ceil(per);
        let parts = SliceParts::new(buf);
        self.run(stripes, |i| {
            let r0 = i * per;
            let nrows = per.min(rows - r0);
            // SAFETY: stripe `i` covers rows [r0, r0+nrows) — disjoint.
            let w = unsafe { parts.window(r0 * row, nrows * row) };
            f(r0, w);
        });
    }
}

/// Shared view over one mutable buffer for tasks that write disjoint windows
/// at non-uniform offsets (the per-`(bh, chunk)` output tiles, whose last
/// chunk may be ragged). The [`run_chunks`](ThreadPool::run_chunks) family
/// covers the uniform-stride cases safely; this is the escape hatch.
///
/// Generic over the element type (default `f32`) so the quantized decode
/// state — `u16` bf16 codes, `i8` int8 codes, their f32 scale vectors — can
/// be windowed per `(seq, head)` task exactly like the f32 buffers.
pub struct SliceParts<'a, T = f32> {
    ptr: *mut T,
    len: usize,
    _life: PhantomData<&'a mut [T]>,
}

// SAFETY: windows handed out by `window` are required (by its contract) to be
// disjoint across concurrent tasks, so sharing the base pointer is sound;
// `T: Send` because windows (`&mut [T]`) cross thread boundaries.
unsafe impl<T: Send> Send for SliceParts<'_, T> {}
unsafe impl<T: Send> Sync for SliceParts<'_, T> {}

impl<'a, T> SliceParts<'a, T> {
    pub fn new(buf: &'a mut [T]) -> Self {
        Self { ptr: buf.as_mut_ptr(), len: buf.len(), _life: PhantomData }
    }

    /// Window `[offset, offset + len)` of the underlying buffer.
    ///
    /// # Safety
    /// Concurrent callers must take non-overlapping windows. Bounds are
    /// checked; disjointness is the caller's contract (one window per task
    /// index, as in the kernel tilings).
    pub unsafe fn window(&self, offset: usize, len: usize) -> &mut [T] {
        assert!(
            offset.checked_add(len).is_some_and(|end| end <= self.len),
            "SliceParts window [{offset}, {offset}+{len}) out of bounds (len {})",
            self.len
        );
        // SAFETY: the range is in bounds (asserted above) and the caller
        // guarantees no other live window overlaps it.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(offset), len) }
    }
}

// Not compiled under the loom feature: these tests drive real OS threads
// outside a `loom::model` run (the loom twins live in `tests/loom_pool.rs`).
//
// The `Relaxed` loads/stores on the `hits`/`outer`/`inner` counters below are
// deliberate and sufficient: `pool.run` only returns after its Acquire load
// of `pending == 0`, which synchronizes with every task's AcqRel decrement —
// the asserting reads therefore happen-after all task writes and need no
// ordering of their own. (Audited alongside the pool's own orderings; the
// TSan CI lane runs these tests under `-Zsanitizer=thread`.)
#[cfg(all(test, not(feature = "loom")))]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn run_visits_every_task_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU32> = (0..37).map(|_| AtomicU32::new(0)).collect();
        pool.run(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
        }
    }

    #[test]
    fn pool_is_reusable_across_many_submissions() {
        // the persistent-worker property: one pool, many jobs, no leaks
        // (size-reduced under Miri, where every round costs interpreter time)
        let rounds = if cfg!(miri) { 10 } else { 200 };
        let pool = ThreadPool::new(3);
        for round in 0..rounds {
            let hits: Vec<AtomicU32> = (0..11).map(|_| AtomicU32::new(0)).collect();
            pool.run(hits.len(), |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "round {round} task {i}");
            }
        }
    }

    #[test]
    fn nested_submission_runs_inline() {
        let pool = ThreadPool::new(4);
        let outer: Vec<AtomicU32> = (0..8).map(|_| AtomicU32::new(0)).collect();
        let inner: Vec<AtomicU32> = (0..8 * 5).map(|_| AtomicU32::new(0)).collect();
        pool.run(outer.len(), |i| {
            outer[i].fetch_add(1, Ordering::Relaxed);
            // would deadlock on a single-job pool without the inline guard
            pool.run(5, |j| {
                inner[i * 5 + j].fetch_add(1, Ordering::Relaxed);
            });
        });
        for h in outer.iter().chain(inner.iter()) {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, |i| {
                assert!(i != 5, "deliberate task failure");
            });
        }));
        assert!(result.is_err(), "task panic must reach the submitter");
        // the pool is still functional for the next submission
        let hits: Vec<AtomicU32> = (0..4).map(|_| AtomicU32::new(0)).collect();
        pool.run(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn panic_mid_batch_still_runs_every_other_task() {
        // a panicking task must not swallow its batch siblings: the drain
        // loop keeps claiming past a failed task, so every other index runs
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU32> = (0..16).map(|_| AtomicU32::new(0)).collect();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(hits.len(), |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
                assert!(i != 3, "deliberate task failure");
            });
        }));
        assert!(result.is_err(), "the panic must reach the submitter");
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} must have run exactly once");
        }
    }

    #[test]
    fn panic_in_nested_submission_propagates_without_deadlock() {
        // the nested (inlined) path: a panic raised inside an inner `run`
        // unwinds through the outer task body, is caught by the outer drain,
        // and reaches the outer submitter — with no worker left waiting
        let pool = ThreadPool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(6, |i| {
                pool.run(4, |j| {
                    assert!(!(i == 2 && j == 1), "deliberate nested failure");
                });
            });
        }));
        assert!(result.is_err(), "the nested panic must reach the outer submitter");
        // every worker survives for the next submission
        let hits: Vec<AtomicU32> = (0..8).map(|_| AtomicU32::new(0)).collect();
        pool.run(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn first_panic_wins_when_several_tasks_fail() {
        // the panic slot keeps one payload; the run must still terminate and
        // deliver a payload when many tasks fail at once
        let pool = ThreadPool::new(4);
        for _ in 0..if cfg!(miri) { 3 } else { 20 } {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.run(8, |i| {
                    panic!("task {i} failed");
                });
            }));
            let payload = result.expect_err("some payload must be delivered");
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_else(|| "<non-string payload>".to_string());
            assert!(msg.contains("failed"), "unexpected payload {msg:?}");
        }
    }

    #[test]
    fn clones_share_the_same_workers() {
        let pool = ThreadPool::new(4);
        let alias = pool.clone();
        assert_eq!(alias.threads(), 4);
        let hits: Vec<AtomicU32> = (0..9).map(|_| AtomicU32::new(0)).collect();
        alias.run(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn run_chunks_covers_buffer_with_correct_indices() {
        for threads in [1, 2, 5] {
            let pool = ThreadPool::new(threads);
            let mut buf = vec![0.0f32; 6 * 4];
            pool.run_chunks(&mut buf, 4, |i, w| {
                for x in w.iter_mut() {
                    *x = i as f32 + 1.0;
                }
            });
            for (i, x) in buf.iter().enumerate() {
                assert_eq!(*x, (i / 4) as f32 + 1.0, "elem {i} (threads {threads})");
            }
        }
    }

    #[test]
    fn run_chunks3_zips_windows_of_different_strides() {
        let pool = ThreadPool::new(3);
        let (ca, cb, cc) = (2, 3, 5);
        let tasks = 7;
        let mut a = vec![0.0f32; tasks * ca];
        let mut b = vec![0.0f32; tasks * cb];
        let mut c = vec![0.0f32; tasks * cc];
        pool.run_chunks3(&mut a, ca, &mut b, cb, &mut c, cc, |i, wa, wb, wc| {
            assert_eq!((wa.len(), wb.len(), wc.len()), (ca, cb, cc));
            wa.fill(i as f32);
            wb.fill(i as f32 + 0.25);
            wc.fill(i as f32 + 0.5);
        });
        for i in 0..tasks {
            assert!(a[i * ca..][..ca].iter().all(|&x| x == i as f32));
            assert!(b[i * cb..][..cb].iter().all(|&x| x == i as f32 + 0.25));
            assert!(c[i * cc..][..cc].iter().all(|&x| x == i as f32 + 0.5));
        }
    }

    #[test]
    fn run_stripes_partitions_rows() {
        let pool = ThreadPool::new(3);
        let mut buf = vec![0.0f32; 10 * 2];
        pool.run_stripes(&mut buf, 2, |first_row, stripe| {
            for (j, row) in stripe.chunks_mut(2).enumerate() {
                row.fill((first_row + j) as f32);
            }
        });
        for (r, row) in buf.chunks(2).enumerate() {
            assert!(row.iter().all(|&x| x == r as f32), "row {r}");
        }
    }

    #[test]
    fn slice_parts_disjoint_windows() {
        let pool = ThreadPool::new(4);
        let mut buf = vec![0.0f32; 23];
        // ragged windows: 6, 6, 6, 5
        let bounds = [(0usize, 6usize), (6, 6), (12, 6), (18, 5)];
        let parts = SliceParts::new(&mut buf);
        pool.run(bounds.len(), |i| {
            let (off, len) = bounds[i];
            // SAFETY: the `bounds` windows are non-overlapping by
            // construction and task `i` takes window `i` only.
            let w = unsafe { parts.window(off, len) };
            w.fill(i as f32 + 1.0);
        });
        assert!(buf[..6].iter().all(|&x| x == 1.0));
        assert!(buf[18..].iter().all(|&x| x == 4.0));
    }

    #[test]
    fn env_zero_means_auto() {
        // Constructors only — reading the real env var here would race other
        // tests; from_env parsing of "0"/garbage is covered by the clamp.
        assert_eq!(ThreadPool::new(0).threads(), 1);
        assert!(ThreadPool::available() >= 1);
        assert!(ThreadPool::global().threads() >= 1);
    }
}
