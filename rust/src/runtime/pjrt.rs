//! PJRT/XLA backend — compiles AOT HLO-text artifacts and executes them via
//! a CPU PJRT client. Gated behind the `pjrt` cargo feature (off by default):
//! the `xla` crate needs a vendored libxla that the hermetic build image does
//! not carry, so enabling the feature also requires uncommenting the `xla`
//! dependency in `Cargo.toml`. See `rust/README.md` for the backend matrix.
//!
//! Interchange contract with the Python build path (`python/compile/aot.py`):
//! - every computation is a file `artifacts/<name>.hlo.txt` (HLO **text** —
//!   the xla crate's 0.5.1 extension rejects jax ≥ 0.5 serialized protos);
//! - `artifacts/manifest.json` records per-artifact input/output specs;
//! - all computations are lowered with `return_tuple=True`, so execution
//!   yields a single tuple literal that the executor decomposes.

use anyhow::{anyhow, bail, Context, Result};
use xla::{ElementType, HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, Shape, XlaComputation};

use super::backend::{Backend, Executor};
use super::manifest::{ArtifactMeta, Manifest};
use super::tensor::Tensor;

fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t {
        Tensor::F32 { data, .. } => Literal::vec1(data),
        Tensor::I32 { data, .. } => Literal::vec1(data),
    };
    Ok(lit.reshape(&dims)?)
}

fn tensor_from_literal(lit: &Literal) -> Result<Tensor> {
    let shape = lit.shape()?;
    let arr = match &shape {
        Shape::Array(a) => a,
        other => bail!("expected array literal, got {other:?}"),
    };
    let dims: Vec<usize> = arr.dims().iter().map(|&d| d as usize).collect();
    match arr.ty() {
        ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
        ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
        other => bail!("unsupported element type {other:?}"),
    }
}

/// One compiled HLO module.
struct PjrtExecutor {
    name: String,
    exe: PjRtLoadedExecutable,
}

impl Executor for PjrtExecutor {
    fn execute(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<Literal> =
            inputs.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let out = self.exe.execute::<Literal>(&lits)?;
        // All artifacts are lowered with return_tuple=True: exactly one
        // result buffer on one device. An artifact violating that contract
        // must error, not panic (out[0][0] was previously indexed unchecked).
        let tuple = out
            .first()
            .and_then(|per_device| per_device.first())
            .ok_or_else(|| {
                anyhow!(
                    "artifact {:?} returned no output buffers (expected one tuple)",
                    self.name
                )
            })?
            .to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.is_empty() {
            bail!("artifact {:?} returned an empty output tuple", self.name);
        }
        parts.iter().map(tensor_from_literal).collect()
    }
}

/// PJRT client over a discovered `artifacts/` directory.
pub struct PjrtBackend {
    client: PjRtClient,
    manifest: Manifest,
}

impl PjrtBackend {
    /// Backend over a loaded manifest.
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, manifest })
    }

    /// Backend over the discovered `artifacts/` directory
    /// (`$REPRO_ARTIFACTS`, else `./artifacts` walking up).
    pub fn discover() -> Result<Self> {
        Self::new(Manifest::discover()?)
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn manifest(&self) -> Result<Manifest> {
        Ok(self.manifest.clone())
    }

    fn load(&self, name: &str, _meta: &ArtifactMeta) -> Result<Box<dyn Executor>> {
        let path = self.manifest.hlo_path(name)?;
        let proto = HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name:?}"))?;
        Ok(Box::new(PjrtExecutor { name: name.to_string(), exe }))
    }
}
