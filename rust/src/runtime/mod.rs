//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! The interchange contract with the Python build path (`python/compile/aot.py`):
//! - every computation is a file `artifacts/<name>.hlo.txt` (HLO **text** —
//!   the xla crate's 0.5.1 extension rejects jax ≥ 0.5 serialized protos);
//! - `artifacts/manifest.json` records per-artifact input/output specs and
//!   metadata (kind, impl, N, D, model config, parameter names);
//! - all computations are lowered with `return_tuple=True`, so execution
//!   yields a single tuple literal that [`Executable::run`] decomposes.

mod engine;
mod manifest;
mod tensor;

pub use engine::{Engine, Executable};
pub use manifest::{ArtifactMeta, IoSpec, Manifest};
pub use tensor::{DType, Tensor};
