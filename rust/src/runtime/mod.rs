//! Multi-backend runtime: a [`Backend`] names artifacts and binds them to
//! [`Executor`]s; the [`Engine`] caches loaded [`Executable`]s and the rest
//! of the stack (coordinator, bench, tasks, CLI) is backend-agnostic.
//!
//! Backends:
//! - **native** (default, always available) — `crate::native`, pure-Rust CPU
//!   implementations of the paper's kernels and the tiny LM; zero external
//!   artifacts, hermetic build.
//! - **pjrt** (cargo feature `pjrt`, `REPRO_BACKEND=pjrt`) — compiles AOT
//!   HLO-text artifacts produced by `python/compile/aot.py` via PJRT.

#![forbid(unsafe_code)]

pub mod backend;
mod engine;
mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
mod tensor;

pub use backend::{Backend, Executor};
pub use engine::{Engine, Executable};
pub use manifest::{ArtifactMeta, IoSpec, Manifest};
pub use tensor::{DType, Tensor};
