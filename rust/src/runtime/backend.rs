//! The backend abstraction: every execution engine (native CPU, PJRT, …)
//! implements these two traits and the rest of the stack — coordinator,
//! bench harness, task scorer, CLI — stays backend-agnostic.
//!
//! Contract:
//! - a backend *names* its computations via a [`Manifest`] (the same schema
//!   the AOT Python path emits as `artifacts/manifest.json`);
//! - [`Backend::load`] binds one named artifact to an [`Executor`];
//! - executors run on host [`Tensor`]s in, host tensors out. Device-resident
//!   state (if any) is the backend's private business; the native backend has
//!   none, so host tensors ARE the hot-path representation.

use anyhow::{bail, Result};

use super::manifest::{ArtifactMeta, Manifest};
use super::tensor::Tensor;

/// A loaded, ready-to-run computation (one artifact).
pub trait Executor {
    /// Execute on host tensors; inputs are borrowed, outputs are owned.
    ///
    /// Implementations must return at least one output tensor or an error —
    /// callers rely on `out[0]` being addressable (the engine enforces this
    /// with a descriptive error either way).
    fn execute(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>>;

    /// Execute with owned, mutable state: `state` holds the artifact's
    /// leading inputs and is updated **in place**; `inputs` are the trailing
    /// non-state inputs (tokens, step counters, …). Returns only the
    /// auxiliary outputs (loss, metrics, …).
    ///
    /// Contract: the artifact's outputs are `aux ++ state'` with
    /// `state'.len() == state.len()`. The default implementation routes
    /// through [`execute`](Self::execute) and writes the returned state back
    /// over `state` — correct for any backend, but still paying the full
    /// reallocation. Backends that can mutate host buffers directly (the
    /// native CPU path) override this to skip the per-step state rebuild.
    fn execute_mut(&self, state: &mut [Tensor], inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let mut refs: Vec<&Tensor> = state.iter().collect();
        refs.extend_from_slice(inputs);
        let mut out = self.execute(&refs)?;
        if out.len() < state.len() {
            bail!(
                "execute_mut fallback: artifact returned {} outputs, fewer than the {} \
                 state arrays it must refresh",
                out.len(),
                state.len()
            );
        }
        let aux = out.len() - state.len();
        for (slot, t) in state.iter_mut().zip(out.drain(aux..)) {
            *slot = t;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy executor with the `aux ++ state'` output contract: takes
    /// `state ++ [delta]`, returns `[count] ++ (state + delta)`.
    struct AddDelta;

    impl Executor for AddDelta {
        fn execute(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
            let (state, delta) = inputs.split_at(inputs.len() - 1);
            let d = delta[0].scalar()?;
            let mut out = vec![Tensor::scalar_f32(state.len() as f32)];
            for t in state {
                let data = t.as_f32()?.iter().map(|&x| x + d).collect();
                out.push(Tensor::f32(t.shape().to_vec(), data)?);
            }
            Ok(out)
        }
    }

    #[test]
    fn execute_mut_fallback_writes_state_back() {
        let mut state = vec![
            Tensor::f32(vec![2], vec![1.0, 2.0]).unwrap(),
            Tensor::f32(vec![1], vec![10.0]).unwrap(),
        ];
        let delta = Tensor::scalar_f32(0.5);
        let aux = AddDelta.execute_mut(&mut state, &[&delta]).unwrap();
        assert_eq!(aux.len(), 1);
        assert_eq!(aux[0].scalar().unwrap(), 2.0);
        assert_eq!(state[0].as_f32().unwrap(), &[1.5, 2.5]);
        assert_eq!(state[1].as_f32().unwrap(), &[10.5]);
    }

    #[test]
    fn execute_mut_fallback_rejects_short_output() {
        struct TooFew;
        impl Executor for TooFew {
            fn execute(&self, _inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
                Ok(vec![Tensor::scalar_f32(0.0)])
            }
        }
        let mut state = vec![Tensor::scalar_f32(1.0), Tensor::scalar_f32(2.0)];
        assert!(TooFew.execute_mut(&mut state, &[]).is_err());
    }
}

/// An execution engine: enumerates artifacts and instantiates executors.
pub trait Backend {
    /// Short platform tag (`"cpu"` for both the native and CPU-PJRT paths).
    fn platform(&self) -> String;

    /// Enumerate the artifacts this backend can execute.
    fn manifest(&self) -> Result<Manifest>;

    /// Instantiate (compile / bind) one artifact. `meta` is the manifest
    /// entry for `name`, already validated to exist.
    fn load(&self, name: &str, meta: &ArtifactMeta) -> Result<Box<dyn Executor>>;
}
