//! The backend abstraction: every execution engine (native CPU, PJRT, …)
//! implements these two traits and the rest of the stack — coordinator,
//! bench harness, task scorer, CLI — stays backend-agnostic.
//!
//! Contract:
//! - a backend *names* its computations via a [`Manifest`] (the same schema
//!   the AOT Python path emits as `artifacts/manifest.json`);
//! - [`Backend::load`] binds one named artifact to an [`Executor`];
//! - executors run on host [`Tensor`]s in, host tensors out. Device-resident
//!   state (if any) is the backend's private business; the native backend has
//!   none, so host tensors ARE the hot-path representation.

use anyhow::Result;

use super::manifest::{ArtifactMeta, Manifest};
use super::tensor::Tensor;

/// A loaded, ready-to-run computation (one artifact).
pub trait Executor {
    /// Execute on host tensors; inputs are borrowed, outputs are owned.
    ///
    /// Implementations must return at least one output tensor or an error —
    /// callers rely on `out[0]` being addressable (the engine enforces this
    /// with a descriptive error either way).
    fn execute(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>>;
}

/// An execution engine: enumerates artifacts and instantiates executors.
pub trait Backend {
    /// Short platform tag (`"cpu"` for both the native and CPU-PJRT paths).
    fn platform(&self) -> String;

    /// Enumerate the artifacts this backend can execute.
    fn manifest(&self) -> Result<Manifest>;

    /// Instantiate (compile / bind) one artifact. `meta` is the manifest
    /// entry for `name`, already validated to exist.
    fn load(&self, name: &str, meta: &ArtifactMeta) -> Result<Box<dyn Executor>>;
}
