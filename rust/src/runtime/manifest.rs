//! `artifacts/manifest.json` parsing — the build-time contract with aot.py.
//!
//! Parsed with the in-tree JSON reader (`crate::util::json`); unknown fields
//! are ignored so the Python side can extend the manifest freely.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Input/output buffer spec of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub index: usize,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * 4
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            index: v.req("index")?.as_usize().ok_or_else(|| anyhow!("bad index"))?,
            dtype: v
                .req("dtype")?
                .as_str()
                .ok_or_else(|| anyhow!("bad dtype"))?
                .to_string(),
            shape: v
                .req("shape")?
                .as_arr()
                .ok_or_else(|| anyhow!("bad shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?,
        })
    }
}

/// Per-artifact metadata (superset across artifact kinds).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub hash: String,
    pub kind: String,
    pub impl_name: Option<String>,
    pub bh: Option<usize>,
    pub n: Option<usize>,
    pub d: Option<usize>,
    pub chunk: Option<usize>,
    pub preset: Option<String>,
    pub attn: Option<String>,
    pub batch: Option<usize>,
    pub n_params: Option<u64>,
    pub n_param_arrays: Option<usize>,
    pub param_names: Option<Vec<String>>,
    pub model: Option<Json>,
    pub train: Option<Json>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactMeta {
    fn from_json(v: &Json) -> Result<Self> {
        let get_str = |k: &str| v.get(k).and_then(Json::as_str).map(str::to_string);
        let get_usize = |k: &str| v.get(k).and_then(Json::as_usize);
        let specs = |k: &str| -> Result<Vec<IoSpec>> {
            v.req(k)?
                .as_arr()
                .ok_or_else(|| anyhow!("{k} is not an array"))?
                .iter()
                .map(IoSpec::from_json)
                .collect()
        };
        Ok(Self {
            file: get_str("file").ok_or_else(|| anyhow!("missing file"))?,
            hash: get_str("hash").unwrap_or_default(),
            kind: get_str("kind").ok_or_else(|| anyhow!("missing kind"))?,
            impl_name: get_str("impl"),
            bh: get_usize("bh"),
            n: get_usize("n"),
            d: get_usize("d"),
            chunk: get_usize("chunk"),
            preset: get_str("preset"),
            attn: get_str("attn"),
            batch: get_usize("batch"),
            n_params: v.get("n_params").and_then(Json::as_f64).map(|x| x as u64),
            n_param_arrays: get_usize("n_param_arrays"),
            param_names: v.get("param_names").and_then(Json::as_arr).map(|a| {
                a.iter()
                    .filter_map(Json::as_str)
                    .map(str::to_string)
                    .collect()
            }),
            model: v.get("model").cloned(),
            train: v.get("train").cloned(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }

    /// The attention implementation this artifact benchmarks, if any.
    pub fn implementation(&self) -> Option<&str> {
        self.impl_name.as_deref()
    }

    /// Model-config field of an LM artifact (from the embedded config dict).
    pub fn model_field_usize(&self, key: &str) -> Option<usize> {
        self.model.as_ref()?.get(key)?.as_usize()
    }

    /// Train-config field of an LM artifact.
    pub fn train_field_f64(&self, key: &str) -> Option<f64> {
        self.train.as_ref()?.get(key)?.as_f64()
    }
}

/// The parsed manifest: artifact name → metadata.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub jax: String,
    pub preset: String,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn from_json_text(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let mut artifacts = BTreeMap::new();
        for (name, meta) in v
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow!("artifacts is not an object"))?
        {
            artifacts.insert(
                name.clone(),
                ArtifactMeta::from_json(meta)
                    .with_context(|| format!("artifact {name:?}"))?,
            );
        }
        Ok(Self {
            version: v.get("version").and_then(Json::as_usize).unwrap_or(0) as u32,
            jax: v.get("jax").and_then(Json::as_str).unwrap_or("").to_string(),
            preset: v.get("preset").and_then(Json::as_str).unwrap_or("").to_string(),
            artifacts,
            dir: PathBuf::new(),
        })
    }

    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let mut m = Self::from_json_text(&text)
            .with_context(|| format!("parsing {path:?}"))?;
        m.dir = dir.to_path_buf();
        Ok(m)
    }

    /// Locate the artifact directory: `$REPRO_ARTIFACTS`, else `./artifacts`,
    /// walking up from the current directory (tests run from target subdirs).
    pub fn discover() -> Result<Self> {
        if let Ok(dir) = std::env::var("REPRO_ARTIFACTS") {
            return Self::load(dir);
        }
        let mut cur = std::env::current_dir()?;
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return Self::load(cand);
            }
            if !cur.pop() {
                return Err(anyhow!(
                    "no artifacts/manifest.json found — run `make artifacts`"
                ));
            }
        }
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest (preset {:?})", self.preset))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }

    /// All artifacts of a given kind, sorted by name.
    pub fn by_kind<'a>(&'a self, kind: &str) -> Vec<(&'a String, &'a ArtifactMeta)> {
        self.artifacts.iter().filter(|(_, a)| a.kind == kind).collect()
    }

    /// Layer artifacts for one implementation, ordered by N then D.
    pub fn layer_sweep<'a>(
        &'a self,
        kind: &str,
        impl_name: &str,
    ) -> Vec<(&'a String, &'a ArtifactMeta)> {
        let mut v: Vec<_> = self
            .artifacts
            .iter()
            .filter(|(name, a)| {
                a.kind == kind
                    && a.implementation() == Some(impl_name)
                    && !name.starts_with("quickstart")
            })
            .collect();
        v.sort_by_key(|(_, a)| (a.n.unwrap_or(0), a.d.unwrap_or(0)));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "jax": "0.8.2", "preset": "default",
      "artifacts": {
        "layer_ours_fwd_n1024_d128": {
          "file": "layer_ours_fwd_n1024_d128.hlo.txt", "hash": "abc",
          "kind": "layer_fwd", "impl": "ours", "bh": 4, "n": 1024, "d": 128,
          "chunk": 128,
          "inputs": [{"index":0,"dtype":"f32","shape":[4,1024,128]}],
          "outputs": [{"index":0,"dtype":"f32","shape":[4,1024,128]}]
        },
        "lm_tiny_ours_train_step": {
          "file": "lm.hlo.txt", "hash": "def", "kind": "lm_train_step",
          "batch": 2, "n_param_arrays": 3,
          "model": {"n_ctx": 128, "vocab_size": 256},
          "train": {"lr_max": 0.001},
          "inputs": [], "outputs": []
        }
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        let a = m.artifacts.get("layer_ours_fwd_n1024_d128").unwrap();
        assert_eq!(a.implementation(), Some("ours"));
        assert_eq!(a.n, Some(1024));
        assert_eq!(a.inputs[0].numel(), 4 * 1024 * 128);
        assert_eq!(a.inputs[0].size_bytes(), 4 * 1024 * 128 * 4);
    }

    #[test]
    fn lm_meta_fields() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        let a = m.artifacts.get("lm_tiny_ours_train_step").unwrap();
        assert_eq!(a.model_field_usize("n_ctx"), Some(128));
        assert_eq!(a.train_field_f64("lr_max"), Some(1e-3));
        assert_eq!(a.batch, Some(2));
    }

    #[test]
    fn by_kind_filters() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        assert_eq!(m.by_kind("layer_fwd").len(), 1);
        assert_eq!(m.by_kind("lm_init").len(), 0);
        assert_eq!(m.layer_sweep("layer_fwd", "ours").len(), 1);
        assert_eq!(m.layer_sweep("layer_fwd", "gated").len(), 0);
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        assert!(m.get("nope").is_err());
    }
}
