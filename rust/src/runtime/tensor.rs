//! Host-side tensor: the dense, row-major value type every backend consumes
//! and produces. The native backend computes on these directly; the optional
//! PJRT backend converts to/from `xla::Literal` at its boundary.

use anyhow::{anyhow, bail, Result};

/// Element type of a [`Tensor`] (the subset our artifacts use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn from_tag(tag: &str) -> Result<Self> {
        match tag {
            "f32" => Ok(DType::F32),
            "i32" | "s32" => Ok(DType::I32),
            other => bail!("unsupported dtype tag {other:?}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// A dense host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Tensor::F32 { shape, data })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Tensor::I32 { shape, data })
    }

    pub fn zeros(dtype: DType, shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        match dtype {
            DType::F32 => Tensor::F32 { shape, data: vec![0.0; n] },
            DType::I32 => Tensor::I32 { shape, data: vec![0; n] },
        }
    }

    /// Scalar i32 (rank-0) — seeds, step counters.
    pub fn scalar_i32(v: i32) -> Self {
        Tensor::I32 { shape: vec![], data: vec![v] }
    }

    /// Scalar f32 (rank-0).
    pub fn scalar_f32(v: f32) -> Self {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// First element as f32 (for rank-0 losses/metrics).
    pub fn scalar(&self) -> Result<f32> {
        match self {
            Tensor::F32 { data, .. } => {
                data.first().copied().ok_or_else(|| anyhow!("empty tensor"))
            }
            Tensor::I32 { data, .. } => {
                data.first().map(|v| *v as f32).ok_or_else(|| anyhow!("empty tensor"))
            }
        }
    }

    /// Mutable f32 view (native-backend parameter updates).
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Deterministic pseudo-random normal tensor (Box–Muller over splitmix64);
    /// used to generate benchmark inputs without a Python round trip.
    pub fn randn(shape: Vec<usize>, seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut rng = crate::data::rng::SplitMix64::new(seed);
        let mut data = Vec::with_capacity(n);
        while data.len() < n {
            let u1 = rng.next_f64().max(1e-12);
            let u2 = rng.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            data.push((r * th.cos()) as f32);
            if data.len() < n {
                data.push((r * th.sin()) as f32);
            }
        }
        Tensor::F32 { shape, data }
    }

    /// Row-normalize the last axis to unit L2 norm (paper §3.3) — used to
    /// build well-conditioned q/k bench inputs host-side.
    pub fn normalize_rows(&mut self) {
        if let Tensor::F32 { shape, data } = self {
            let d = *shape.last().unwrap_or(&1);
            if d == 0 {
                return;
            }
            for row in data.chunks_mut(d) {
                let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt() + 1e-6;
                for x in row.iter_mut() {
                    *x /= norm;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(vec![4], vec![1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar_i32(42);
        assert_eq!(t.shape(), &[] as &[usize]);
        assert_eq!(t.scalar().unwrap(), 42.0);
    }

    #[test]
    fn randn_is_deterministic_and_normalish() {
        let a = Tensor::randn(vec![64, 32], 7);
        let b = Tensor::randn(vec![64, 32], 7);
        assert_eq!(a, b);
        let mean: f32 =
            a.as_f32().unwrap().iter().sum::<f32>() / a.numel() as f32;
        assert!(mean.abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn normalize_rows_unit_norm() {
        let mut t = Tensor::randn(vec![8, 16], 3);
        t.normalize_rows();
        for row in t.as_f32().unwrap().chunks(16) {
            let n = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-3, "norm {n}");
        }
    }

    #[test]
    fn size_accounting() {
        let t = Tensor::zeros(DType::F32, vec![4, 256, 64]);
        assert_eq!(t.numel(), 65536);
        assert_eq!(t.size_bytes(), 262144);
    }
}
