//! The PJRT engine: one client, a cache of compiled executables.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{ArtifactMeta, Manifest};
use super::tensor::Tensor;

/// A compiled artifact ready to execute.
pub struct Executable {
    pub name: String,
    pub meta: ArtifactMeta,
    exe: PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; returns the decomposed output tuple.
    ///
    /// All artifacts are lowered with `return_tuple=True`, so PJRT hands back
    /// a single tuple buffer which we sync to host and split.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.check_inputs(inputs)?;
        let lits: Vec<Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        self.run_literals(&lits)
    }

    /// Execute with pre-converted literals (hot path: skips re-encoding
    /// inputs that do not change between calls).
    pub fn run_literals(&self, lits: &[Literal]) -> Result<Vec<Tensor>> {
        let out = self.exe.execute::<Literal>(lits)?;
        let tuple = out[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Like [`Self::run_literals`] but borrowing the inputs (avoids cloning
    /// large state literals when only a subset is passed).
    pub fn run_literals_ref(&self, lits: &[&Literal]) -> Result<Vec<Tensor>> {
        let out = self.exe.execute::<&Literal>(lits)?;
        let tuple = out[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Execute and return raw literals (hot path for the train loop: the
    /// state literals round-trip without `Tensor` re-materialization).
    pub fn run_to_literals(&self, lits: &[Literal]) -> Result<Vec<Literal>> {
        let out = self.exe.execute::<Literal>(lits)?;
        let tuple = out[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Execute and time only the device execution + output sync.
    pub fn run_timed(&self, lits: &[Literal]) -> Result<(Vec<Tensor>, f64)> {
        let t0 = Instant::now();
        let out = self.exe.execute::<Literal>(lits)?;
        let tuple = out[0][0].to_literal_sync()?;
        let secs = t0.elapsed().as_secs_f64();
        let parts = tuple.to_tuple()?;
        Ok((parts.iter().map(Tensor::from_literal).collect::<Result<_>>()?, secs))
    }

    fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (spec, t) in self.meta.inputs.iter().zip(inputs) {
            if spec.shape != t.shape() {
                bail!(
                    "{} input #{}: expected shape {:?}, got {:?}",
                    self.name,
                    spec.index,
                    spec.shape,
                    t.shape()
                );
            }
        }
        Ok(())
    }

    /// Total input bytes (for throughput accounting).
    pub fn input_bytes(&self) -> usize {
        self.meta.inputs.iter().map(|s| s.size_bytes()).sum()
    }

    /// Total output bytes.
    pub fn output_bytes(&self) -> usize {
        self.meta.outputs.iter().map(|s| s.size_bytes()).sum()
    }
}

/// PJRT client + manifest + executable cache.
///
/// Cheap to clone conceptually but owns FFI handles — share via `Rc` (the
/// coordinator is single-threaded around the PJRT calls; XLA parallelizes
/// internally).
pub struct Engine {
    client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    /// Create a CPU-PJRT engine over a loaded manifest.
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Engine over the discovered `artifacts/` directory.
    pub fn discover() -> Result<Self> {
        Self::new(Manifest::discover()?)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (memoized).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(name)?;
        let proto = HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name:?}"))?;
        let e = Rc::new(Executable { name: name.to_string(), meta, exe });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Compile-time of an artifact (for the §Perf log); bypasses the cache.
    pub fn compile_time(&self, name: &str) -> Result<f64> {
        let path = self.manifest.hlo_path(name)?;
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)?;
        let comp = XlaComputation::from_proto(&proto);
        let _exe = self.client.compile(&comp)?;
        Ok(t0.elapsed().as_secs_f64())
    }
}
