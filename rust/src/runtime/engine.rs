//! The engine: one backend, a cache of loaded executables.
//!
//! `Engine` is generic over [`Backend`] via dynamic dispatch — the per-call
//! overhead is one vtable hop, irrelevant next to any kernel's work. The
//! default backend is the dependency-free native CPU executor; the PJRT/XLA
//! path compiles behind the off-by-default `pjrt` cargo feature and is
//! selected at runtime with `REPRO_BACKEND=pjrt`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::backend::{Backend, Executor};
use super::manifest::{ArtifactMeta, Manifest};
use super::tensor::Tensor;

/// A loaded artifact ready to execute.
pub struct Executable {
    pub name: String,
    pub meta: ArtifactMeta,
    exec: Box<dyn Executor>,
}

impl Executable {
    /// Execute with host tensors, checking shapes against the manifest spec.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.check_inputs(inputs)?;
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// Execute with borrowed tensors (hot path: the training state round-trips
    /// without cloning; shape checks are skipped — the caller owns the
    /// contract).
    pub fn run_refs(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let out = self.exec.execute(inputs)?;
        if out.is_empty() {
            bail!("artifact {:?} returned no outputs", self.name);
        }
        Ok(out)
    }

    /// Execute with owned, mutable leading state (the training hot path):
    /// the backend updates `state` in place — the native executor mutates
    /// the buffers directly with zero state reallocation; other backends
    /// fall back to execute-and-write-back. `aux_inputs` are the trailing
    /// non-state inputs; returns the auxiliary outputs (loss, metrics, …),
    /// of which there must be at least one.
    pub fn run_owned(&self, state: &mut [Tensor], aux_inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let out = self.exec.execute_mut(state, aux_inputs)?;
        if out.is_empty() {
            bail!("artifact {:?} returned no auxiliary outputs", self.name);
        }
        Ok(out)
    }

    /// Execute and time only the backend execution.
    pub fn run_timed(&self, inputs: &[&Tensor]) -> Result<(Vec<Tensor>, f64)> {
        let t0 = Instant::now();
        let out = self.exec.execute(inputs)?;
        let secs = t0.elapsed().as_secs_f64();
        if out.is_empty() {
            bail!("artifact {:?} returned no outputs", self.name);
        }
        Ok((out, secs))
    }

    fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (spec, t) in self.meta.inputs.iter().zip(inputs) {
            if spec.shape != t.shape() {
                bail!(
                    "{} input #{}: expected shape {:?}, got {:?}",
                    self.name,
                    spec.index,
                    spec.shape,
                    t.shape()
                );
            }
        }
        Ok(())
    }

    /// Total input bytes (for throughput accounting).
    pub fn input_bytes(&self) -> usize {
        self.meta.inputs.iter().map(|s| s.size_bytes()).sum()
    }

    /// Total output bytes.
    pub fn output_bytes(&self) -> usize {
        self.meta.outputs.iter().map(|s| s.size_bytes()).sum()
    }
}

/// Backend + manifest + executable cache.
///
/// Owns the backend via `Box<dyn Backend>`; share the engine itself by
/// reference (the coordinator is single-threaded around backend calls).
pub struct Engine {
    backend: Box<dyn Backend>,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    /// Engine over an explicit backend instance.
    pub fn with_backend(backend: Box<dyn Backend>) -> Result<Self> {
        let manifest = backend.manifest()?;
        Ok(Self { backend, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// The dependency-free native CPU backend (always available).
    pub fn native() -> Result<Self> {
        Self::with_backend(Box::new(crate::native::NativeBackend::new()))
    }

    /// Select a backend from the environment: `REPRO_BACKEND=native` (the
    /// default) or `REPRO_BACKEND=pjrt` (requires the `pjrt` cargo feature
    /// and an `artifacts/` directory produced by `make artifacts`).
    pub fn discover() -> Result<Self> {
        let which = std::env::var("REPRO_BACKEND").unwrap_or_else(|_| "native".to_string());
        match which.as_str() {
            "native" => Self::native(),
            #[cfg(feature = "pjrt")]
            "pjrt" => Self::with_backend(Box::new(super::pjrt::PjrtBackend::discover()?)),
            other => bail!(
                "backend {other:?} is not available in this build \
                 (compiled backends: native{})",
                if cfg!(feature = "pjrt") { ", pjrt" } else { "" }
            ),
        }
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Load an artifact (memoized).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self.manifest.get(name)?.clone();
        let exec = self.backend.load(name, &meta)?;
        let e = Rc::new(Executable { name: name.to_string(), meta, exec });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }
}
