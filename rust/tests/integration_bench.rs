//! Integration: sweep runner + report emitters over the smallest artifacts,
//! and the task scorer over a freshly-initialized model.

// Too slow under the Miri interpreter (and process-spawning tests cannot
// run there at all) -- the Miri lane drives tests/miri_parity.rs instead.
#![cfg(not(miri))]

use repro::bench::{report as rpt, SweepRunner};
use repro::runtime::{Engine, Tensor};
use repro::simulator::{DeviceSpec, TrafficModel};
use repro::tasks::{score_task, TaskKind};

#[test]
fn sweep_runs_smallest_ours_artifact() {
    let engine = Engine::discover().unwrap();
    let mut runner = SweepRunner::new(&engine);
    runner.reps = 2;
    let p = runner.run_artifact("layer_ours_fwd_n1024_d128").unwrap();
    assert_eq!(p.impl_name, "ours");
    assert_eq!(p.n, 1024);
    assert!(p.cpu_s.p50 > 0.0);
    assert!(p.model_total_s > 0.0);
    assert!(p.mem_bytes > 0.0);
    assert!(p.cpu_s.min <= p.cpu_s.p50 && p.cpu_s.p50 <= p.cpu_s.max);
}

#[test]
fn sweep_series_is_sorted_and_linear_in_n() {
    let engine = Engine::discover().unwrap();
    let mut runner = SweepRunner::new(&engine);
    runner.reps = 2;
    // limit to the two smallest points for test speed
    runner.max_bytes = usize::MAX;
    let names: Vec<String> = engine
        .manifest
        .layer_sweep("layer_fwd", "ours")
        .iter()
        .map(|(n, _)| (*n).clone())
        .take(2)
        .collect();
    let pts: Vec<_> = names
        .iter()
        .map(|n| runner.run_artifact(n).unwrap())
        .collect();
    assert_eq!(pts.len(), 2);
    assert!(pts[0].n < pts[1].n);
    // the model (analytic) must scale linearly: 2× N → ≈2× time
    let ratio = pts[1].model_total_s / pts[0].model_total_s;
    assert!(ratio > 1.5 && ratio < 2.5, "model ratio {ratio}");
}

#[test]
fn report_emitters_cover_points() {
    let engine = Engine::discover().unwrap();
    let mut runner = SweepRunner::new(&engine);
    runner.reps = 1;
    let p = runner.run_artifact("layer_ours_fwd_n1024_d128").unwrap();
    let csv = rpt::sweep_csv(&[p.clone()]);
    assert_eq!(csv.lines().count(), 2);
    assert!(csv.contains("ours"));
    let md = rpt::sweep_markdown("t", &[p]);
    assert!(md.contains("| ours | 1024 | 128 | 128 |"));
}

#[test]
fn fits_rejects_giant_quadratic_artifacts() {
    let engine = Engine::discover().unwrap();
    let mut runner = SweepRunner::new(&engine);
    runner.max_bytes = 1 << 20; // 1 MB budget: nothing quadratic fits
    assert!(!runner.fits("layer_softmax_fwd_n4096_d128"));
    runner.max_bytes = usize::MAX;
    assert!(runner.fits("layer_softmax_fwd_n4096_d128"));
}

#[test]
fn table1_and_fig4_render() {
    let m = TrafficModel::new(DeviceSpec::a6000());
    let t1 = rpt::table1_markdown(&m);
    assert!(t1.contains("Our LA"));
    let f4 = rpt::fig4_markdown(&m, &[4096, 8192]);
    assert!(f4.contains("ours"));
}

#[test]
fn task_scorer_runs_on_fresh_init() {
    let engine = Engine::discover().unwrap();
    // build params via the init artifact (untrained — accuracy is near chance,
    // the point is the scoring path end-to-end)
    let init = engine.load("lm_tiny_ours_init").unwrap();
    let state = init.run(&[Tensor::scalar_i32(0)]).unwrap();
    let s = score_task(
        &engine,
        "lm_tiny_ours_logits",
        &state,
        TaskKind::Copy,
        8,
        0,
    )
    .unwrap();
    assert!(s.positions > 0);
    assert!(s.correct <= s.positions);
    assert!(s.accuracy() >= 0.0 && s.accuracy() <= 1.0);
}
