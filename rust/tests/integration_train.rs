//! Integration: the full training coordinator over the lm-tiny artifacts.

// Too slow under the Miri interpreter (and process-spawning tests cannot
// run there at all) -- the Miri lane drives tests/miri_parity.rs instead.
#![cfg(not(miri))]

use repro::coordinator::config::{DataSection, OutputSection, TrainSection};
use repro::coordinator::{Checkpoint, RunConfig, Trainer};
use repro::runtime::Engine;

fn cfg(attn: &str, steps: usize, dir: &str) -> RunConfig {
    RunConfig {
        train: TrainSection {
            preset: "tiny".into(),
            attn: attn.into(),
            steps,
            eval_every: steps.max(2) / 2,
            ckpt_every: 0,
            seed: 0,
        },
        data: DataSection { corpus_bytes: 1 << 20, val_frac: 0.1 },
        output: OutputSection { dir: dir.into() },
    }
}

fn tmpdir(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("repro_it_{tag}"));
    std::fs::create_dir_all(&d).unwrap();
    d.to_string_lossy().into_owned()
}

#[test]
fn training_reduces_loss_and_writes_metrics() {
    let engine = Engine::discover().unwrap();
    let dir = tmpdir("train");
    let trainer = Trainer::new(&engine, cfg("ours", 12, &dir)).unwrap();
    let outcome = trainer.run().unwrap();
    assert!(outcome.final_loss.is_finite());
    // loss must drop well below the ln(V)≈5.55 random baseline
    assert!(outcome.final_loss < 5.4, "loss {}", outcome.final_loss);
    assert!(outcome.final_val_loss.is_some());
    assert!(outcome.tokens_per_s > 0.0);
    assert!(outcome.run_dir.join("metrics.jsonl").exists());
    assert!(outcome.run_dir.join("metrics.csv").exists());
    assert!(outcome.run_dir.join("final.ckpt").exists());

    // metrics are readable and strictly ordered by step
    let log = repro::coordinator::MetricsLog::read_jsonl(
        outcome.run_dir.join("metrics.jsonl"),
    )
    .unwrap();
    assert_eq!(log.records().len(), 12);
    for (i, r) in log.records().iter().enumerate() {
        assert_eq!(r.step, i);
    }
    // first-step loss ≈ ln(256) for fresh init; final strictly lower
    let first = log.records()[0].loss;
    assert!(first > 5.0 && first < 6.2, "init loss {first}");
    assert!(log.records().last().unwrap().loss < first);
    // every step logged a finite pre-clip grad norm
    for r in log.records() {
        let gn = r.grad_norm.expect("grad_norm logged");
        assert!(gn.is_finite() && gn > 0.0, "step {}: grad_norm {gn}", r.step);
    }
}

/// Regression: `steps = 0` used to underflow `steps - 1` at the final
/// checkpoint; it must now save the freshly-initialized state cleanly.
#[test]
fn zero_step_run_saves_initial_state() {
    let engine = Engine::discover().unwrap();
    let dir = tmpdir("zerostep");
    let trainer = Trainer::new(&engine, cfg("ours", 0, &dir)).unwrap();
    let outcome = trainer.run().unwrap();
    assert_eq!(outcome.steps, 0);
    assert!(outcome.final_loss.is_nan(), "no step ran, no loss measured");
    let ckpt = Checkpoint::load(outcome.run_dir.join("final.ckpt")).unwrap();
    assert_eq!(ckpt.meta.step, 0);
    assert!(ckpt.meta.loss.is_nan());
    // the saved state is exactly the init-artifact output, restorable as-is
    assert_eq!(ckpt.state, trainer.init_state().unwrap());
    assert!(trainer.restore(&ckpt).is_ok());
}

#[test]
fn checkpoint_roundtrip_resumes_training() {
    let engine = Engine::discover().unwrap();
    let dir = tmpdir("resume");
    let trainer = Trainer::new(&engine, cfg("ours", 4, &dir)).unwrap();
    let outcome = trainer.run().unwrap();
    let ckpt = Checkpoint::load(outcome.run_dir.join("final.ckpt")).unwrap();
    assert_eq!(ckpt.meta.artifact_tag, "lm_tiny_ours");
    assert_eq!(ckpt.meta.step, 3);

    // restore and take one more in-place step — loss stays finite and close
    let mut state = trainer.restore(&ckpt).unwrap();
    let (_tok, ds) = trainer.build_dataset().unwrap();
    let mut b = repro::data::Batcher::new(
        &ds,
        repro::data::Split::Train,
        trainer.batch_size(),
        1,
    )
    .unwrap();
    let m = trainer
        .step(&mut state, &b.next_batch().unwrap(), 4)
        .unwrap();
    assert!(m.loss.is_finite());
    assert!(m.grad_norm.is_finite() && m.grad_norm > 0.0, "grad norm {}", m.grad_norm);
    assert!(
        (m.loss - ckpt.meta.loss).abs() < 2.0,
        "resumed loss {} vs {}",
        m.loss,
        ckpt.meta.loss
    );
}

#[test]
fn restore_rejects_mismatched_tag() {
    let engine = Engine::discover().unwrap();
    let dir = tmpdir("mismatch");
    let t_ours = Trainer::new(&engine, cfg("ours", 2, &dir)).unwrap();
    let outcome = t_ours.run().unwrap();
    let ckpt = Checkpoint::load(outcome.run_dir.join("final.ckpt")).unwrap();
    let t_soft = Trainer::new(&engine, cfg("softmax", 2, &dir)).unwrap();
    assert!(t_soft.restore(&ckpt).is_err());
}

#[test]
fn deterministic_training_given_seed() {
    let engine = Engine::discover().unwrap();
    let d1 = tmpdir("det1");
    let d2 = tmpdir("det2");
    let o1 = Trainer::new(&engine, cfg("ours", 3, &d1)).unwrap().run().unwrap();
    let o2 = Trainer::new(&engine, cfg("ours", 3, &d2)).unwrap().run().unwrap();
    assert_eq!(o1.final_loss, o2.final_loss);
}

#[test]
fn all_three_attention_variants_train() {
    let engine = Engine::discover().unwrap();
    for attn in ["ours", "gated", "softmax"] {
        let dir = tmpdir(&format!("variant_{attn}"));
        let outcome = Trainer::new(&engine, cfg(attn, 3, &dir)).unwrap().run().unwrap();
        assert!(outcome.final_loss.is_finite(), "{attn} diverged");
    }
}

/// The deep preset end-to-end through the Trainer: BPE vocab 512, 4 layers ×
/// 4 heads, checkpoints with the current layout header. Kept to 2 steps and
/// one attention variant — the debug-profile step is ~100× a tiny step; the
/// per-variant coverage lives in `lm_small_artifacts_step_for_every_attn`.
#[test]
fn lm_small_trains_end_to_end() {
    let engine = Engine::discover().unwrap();
    let dir = tmpdir("small");
    let run_cfg = RunConfig {
        train: TrainSection {
            preset: "small".into(),
            attn: "ours".into(),
            steps: 2,
            eval_every: 0,
            ckpt_every: 0,
            seed: 0,
        },
        data: DataSection { corpus_bytes: 130_000, val_frac: 0.1 },
        output: OutputSection { dir },
    };
    let trainer = Trainer::new(&engine, run_cfg).unwrap();
    assert_eq!(trainer.vocab_size(), 512);
    assert!(trainer.n_params() > 500_000, "n_params {}", trainer.n_params());
    assert_eq!(trainer.model_field("n_layer"), Some(4));
    assert_eq!(trainer.model_field("n_head"), Some(4));
    let outcome = trainer.run().unwrap();
    assert!(outcome.final_loss.is_finite());
    // fresh 512-vocab model starts near ln(512) ≈ 6.24
    assert!(outcome.final_loss < 7.0, "loss {}", outcome.final_loss);
    let ckpt = Checkpoint::load(outcome.run_dir.join("final.ckpt")).unwrap();
    assert_eq!(ckpt.meta.artifact_tag, "lm_small_ours");
    assert!(ckpt.meta.require_current_layout().is_ok());
    assert!(trainer.restore(&ckpt).is_ok());
}

/// Every attention variant of the deep preset executes one optimizer step
/// through the artifact interface (init → train_step) and yields a sane
/// fresh-model loss.
#[test]
fn lm_small_artifacts_step_for_every_attn() {
    use repro::runtime::Tensor;
    let engine = Engine::discover().unwrap();
    for attn in ["ours", "gated", "softmax"] {
        let init = engine.load(&format!("lm_small_{attn}_init")).unwrap();
        let state = init.run(&[Tensor::scalar_i32(7)]).unwrap();
        let step_exe = engine.load(&format!("lm_small_{attn}_train_step")).unwrap();
        let batch = step_exe.meta.batch.unwrap();
        let n_ctx = step_exe.meta.model_field_usize("n_ctx").unwrap();
        let vocab = step_exe.meta.model_field_usize("vocab_size").unwrap();
        let n = batch * (n_ctx + 1);
        let toks = Tensor::i32(
            vec![batch, n_ctx + 1],
            (0..n).map(|i| (i % 311) as i32).collect(),
        )
        .unwrap();
        let step_t = Tensor::scalar_i32(0);
        let mut args: Vec<&Tensor> = state.iter().collect();
        args.push(&toks);
        args.push(&step_t);
        let out = step_exe.run_refs(&args).unwrap();
        // outputs: loss + grad_norm + refreshed state
        assert_eq!(out.len(), 2 + state.len(), "{attn}");
        assert!(out[1].scalar().unwrap().is_finite(), "{attn} grad norm");
        let loss = out[0].scalar().unwrap();
        let uniform = (vocab as f32).ln();
        assert!(
            (loss - uniform).abs() < 0.5,
            "{attn}: fresh deep-model loss {loss} vs ln(V) {uniform}"
        );
    }
}
