//! Integration: the full training coordinator over the lm-tiny artifacts.

use repro::coordinator::config::{DataSection, OutputSection, TrainSection};
use repro::coordinator::{Checkpoint, RunConfig, Trainer};
use repro::runtime::Engine;

fn cfg(attn: &str, steps: usize, dir: &str) -> RunConfig {
    RunConfig {
        train: TrainSection {
            preset: "tiny".into(),
            attn: attn.into(),
            steps,
            eval_every: steps.max(2) / 2,
            ckpt_every: 0,
            seed: 0,
        },
        data: DataSection { corpus_bytes: 1 << 20, val_frac: 0.1 },
        output: OutputSection { dir: dir.into() },
    }
}

fn tmpdir(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("repro_it_{tag}"));
    std::fs::create_dir_all(&d).unwrap();
    d.to_string_lossy().into_owned()
}

#[test]
fn training_reduces_loss_and_writes_metrics() {
    let engine = Engine::discover().unwrap();
    let dir = tmpdir("train");
    let trainer = Trainer::new(&engine, cfg("ours", 12, &dir)).unwrap();
    let outcome = trainer.run().unwrap();
    assert!(outcome.final_loss.is_finite());
    // loss must drop well below the ln(V)≈5.55 random baseline
    assert!(outcome.final_loss < 5.4, "loss {}", outcome.final_loss);
    assert!(outcome.final_val_loss.is_some());
    assert!(outcome.tokens_per_s > 0.0);
    assert!(outcome.run_dir.join("metrics.jsonl").exists());
    assert!(outcome.run_dir.join("metrics.csv").exists());
    assert!(outcome.run_dir.join("final.ckpt").exists());

    // metrics are readable and strictly ordered by step
    let log = repro::coordinator::MetricsLog::read_jsonl(
        outcome.run_dir.join("metrics.jsonl"),
    )
    .unwrap();
    assert_eq!(log.records().len(), 12);
    for (i, r) in log.records().iter().enumerate() {
        assert_eq!(r.step, i);
    }
    // first-step loss ≈ ln(256) for fresh init; final strictly lower
    let first = log.records()[0].loss;
    assert!(first > 5.0 && first < 6.2, "init loss {first}");
    assert!(log.records().last().unwrap().loss < first);
}

#[test]
fn checkpoint_roundtrip_resumes_training() {
    let engine = Engine::discover().unwrap();
    let dir = tmpdir("resume");
    let trainer = Trainer::new(&engine, cfg("ours", 4, &dir)).unwrap();
    let outcome = trainer.run().unwrap();
    let ckpt = Checkpoint::load(outcome.run_dir.join("final.ckpt")).unwrap();
    assert_eq!(ckpt.meta.artifact_tag, "lm_tiny_ours");
    assert_eq!(ckpt.meta.step, 3);

    // restore and take one more step — loss stays finite and close
    let state = trainer.restore(&ckpt).unwrap();
    let (_tok, ds) = trainer.build_dataset().unwrap();
    let mut b = repro::data::Batcher::new(
        &ds,
        repro::data::Split::Train,
        trainer.batch_size(),
        1,
    )
    .unwrap();
    let (loss, _new_state) = trainer
        .step(state, &b.next_batch().unwrap(), 4)
        .unwrap();
    assert!(loss.is_finite());
    assert!((loss - ckpt.meta.loss).abs() < 2.0, "resumed loss {loss} vs {}", ckpt.meta.loss);
}

#[test]
fn restore_rejects_mismatched_tag() {
    let engine = Engine::discover().unwrap();
    let dir = tmpdir("mismatch");
    let t_ours = Trainer::new(&engine, cfg("ours", 2, &dir)).unwrap();
    let outcome = t_ours.run().unwrap();
    let ckpt = Checkpoint::load(outcome.run_dir.join("final.ckpt")).unwrap();
    let t_soft = Trainer::new(&engine, cfg("softmax", 2, &dir)).unwrap();
    assert!(t_soft.restore(&ckpt).is_err());
}

#[test]
fn deterministic_training_given_seed() {
    let engine = Engine::discover().unwrap();
    let d1 = tmpdir("det1");
    let d2 = tmpdir("det2");
    let o1 = Trainer::new(&engine, cfg("ours", 3, &d1)).unwrap().run().unwrap();
    let o2 = Trainer::new(&engine, cfg("ours", 3, &d2)).unwrap().run().unwrap();
    assert_eq!(o1.final_loss, o2.final_loss);
}

#[test]
fn all_three_attention_variants_train() {
    let engine = Engine::discover().unwrap();
    for attn in ["ours", "gated", "softmax"] {
        let dir = tmpdir(&format!("variant_{attn}"));
        let outcome = Trainer::new(&engine, cfg(attn, 3, &dir)).unwrap().run().unwrap();
        assert!(outcome.final_loss.is_finite(), "{attn} diverged");
    }
}
