//! Inference-subsystem integration tests: incremental-decode parity against
//! the full-context forward for every `AttnKind`, thread-count-invariant
//! greedy generation, the recurrent-vs-KV-cache state-footprint contract,
//! and checkpoint-load hardening for `generate`/`serve`.

// Too slow under the Miri interpreter (and process-spawning tests cannot
// run there at all) -- the Miri lane drives tests/miri_parity.rs instead.
#![cfg(not(miri))]

use std::io::Cursor;

use repro::coordinator::{Checkpoint, CheckpointMeta, PARAM_LAYOUT_VERSION};
use repro::data::rng::SplitMix64;
use repro::infer::{serve_loop, DecodeState, GenRequest, ModelSession, SampleMode};
use repro::native::model::{self, AttnKind, LmConfig};
use repro::native::pool::ThreadPool;
use repro::runtime::Tensor;
use repro::util::json::Json;

/// Incremental-vs-full tolerance: the step path shares the GEMM microkernels
/// and per-token accumulation order with the full forward, so differences
/// are last-bit rounding from row-count-dependent tiling at most.
const TOL: f32 = 2e-3;

fn param_state(cfg: &LmConfig, seed: u64) -> Vec<Tensor> {
    let mut state = cfg.init_state(seed);
    state.truncate(cfg.n_param_arrays());
    state
}

fn random_tokens(cfg: &LmConfig, seed: u64) -> Vec<i32> {
    let mut rng = SplitMix64::new(seed);
    (0..cfg.batch * cfg.n_ctx).map(|_| rng.below(cfg.vocab) as i32).collect()
}

/// Token-by-token `logits_step` must reproduce the full-context `logits`
/// path at every position, for every mixer family, with the step batched
/// over `cfg.batch` concurrent sequences.
#[test]
fn incremental_decode_matches_full_context_logits() {
    for preset in ["tiny", "small"] {
        for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
            let cfg = LmConfig::by_preset(preset, attn).unwrap();
            let params = param_state(&cfg, 11);
            let refs: Vec<&Tensor> = params.iter().collect();
            let pool = ThreadPool::new(4);
            let toks = random_tokens(&cfg, 7);

            let full = model::logits(
                &cfg,
                &refs,
                &Tensor::i32(vec![cfg.batch, cfg.n_ctx], toks.clone()).unwrap(),
                &pool,
            )
            .unwrap();
            let full = full.as_f32().unwrap();

            let mut st = DecodeState::new(&cfg, cfg.batch).unwrap();
            let v = cfg.vocab;
            // tiny walks its whole window (and checks exhaustion below);
            // the deeper preset caps the incremental sweep to keep the
            // debug-profile test time in check — the recurrence is fully
            // exercised well before 48 steps
            let t_check = if preset == "tiny" { cfg.n_ctx } else { cfg.n_ctx.min(48) };
            for t in 0..t_check {
                // column t of the (batch, n_ctx) token matrix
                let col: Vec<i32> =
                    (0..cfg.batch).map(|b| toks[b * cfg.n_ctx + t]).collect();
                let step = model::logits_step(&cfg, &refs, &col, &mut st, &pool).unwrap();
                for b in 0..cfg.batch {
                    let want = &full[(b * cfg.n_ctx + t) * v..][..v];
                    let got = &step[b * v..][..v];
                    let d = got
                        .iter()
                        .zip(want)
                        .map(|(x, y)| (x - y).abs())
                        .fold(0.0f32, f32::max);
                    assert!(
                        d < TOL,
                        "{preset}/{attn:?}: step logits diverge at t={t} b={b} (max {d})"
                    );
                    assert!(got.iter().all(|x| x.is_finite()), "{preset}/{attn:?} t={t}");
                }
            }
            assert_eq!(st.pos(), t_check);
            if t_check == cfg.n_ctx {
                // the window is exhausted — stepping again must error, not panic
                assert!(model::logits_step(&cfg, &refs, &vec![0; cfg.batch], &mut st, &pool)
                    .is_err());
            }
        }
    }
}

/// The prefill fast path (no unembedding) must advance the state exactly
/// like the logits-producing step: logits after a prefix consumed via
/// `prefill_step` equal logits after the same prefix via `logits_step`.
#[test]
fn prefill_step_advances_state_identically() {
    let pool = ThreadPool::new(2);
    for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
        let cfg = LmConfig::tiny(attn);
        let params = param_state(&cfg, 9);
        let refs: Vec<&Tensor> = params.iter().collect();
        let prefix: Vec<i32> = (0..6usize).map(|i| ((i * 31) % cfg.vocab) as i32).collect();

        let mut fast = DecodeState::new(&cfg, 1).unwrap();
        for &tok in &prefix[..prefix.len() - 1] {
            model::prefill_step(&cfg, &refs, &[tok], &mut fast, &pool).unwrap();
        }
        let a = model::logits_step(&cfg, &refs, &[prefix[5]], &mut fast, &pool).unwrap();

        let mut slow = DecodeState::new(&cfg, 1).unwrap();
        let mut b = Vec::new();
        for &tok in &prefix {
            b = model::logits_step(&cfg, &refs, &[tok], &mut slow, &pool).unwrap();
        }
        assert_eq!(a, b, "{attn:?}: prefill path diverged from the logits path");
        assert_eq!(fast.pos(), slow.pos());
        assert_eq!(fast.state_bytes(), slow.state_bytes());
    }
}

/// Greedy decode from a chunked-prefilled state must match the
/// token-by-token-prefilled state for every mixer family at both preset
/// depths: identical continuation tokens, first-step logits within TOL.
/// Softmax is additionally bit-exact off-simd — the blocked prefill runs
/// the same streaming two-pass softmax in the same accumulation order as
/// the per-token step — while the linear kinds see GEMM-reordered sums
/// (inter/intra chunk split), so they get the rounding tolerance.
#[test]
fn chunked_prefill_matches_serial_prefill_for_every_attn_kind() {
    let pool = ThreadPool::new(4);
    for preset in ["tiny", "small"] {
        for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
            let cfg = LmConfig::by_preset(preset, attn).unwrap();
            let params = param_state(&cfg, 13);
            let refs: Vec<&Tensor> = params.iter().collect();
            let bound = model::DecodeModel::bind(&cfg, &refs).unwrap();
            let steps = 8;
            // leave room in the window for the greedy continuation; cap the
            // deeper preset so the debug-profile serial oracle stays cheap
            let l = (cfg.n_ctx - steps - 1).min(96);
            let toks: Vec<i32> =
                (0..l).map(|i| ((i * 31 + 7) % cfg.vocab) as i32).collect();

            // serial oracle: one prefill_step per prompt token
            let mut st_s = DecodeState::new(&cfg, 1).unwrap();
            let mut dsc = model::DecodeScratch::new();
            for &t in &toks[..l - 1] {
                bound.prefill_step_scratch(&[t], &mut st_s, &pool, &mut dsc).unwrap();
            }

            // chunked route: whole prompt in one pass, ragged tail included
            let mut st_c = DecodeState::new(&cfg, 1).unwrap();
            let mut psc = model::PrefillScratch::new();
            bound.prefill_chunked_with(16, &toks[..l - 1], &mut st_c, &pool, &mut psc).unwrap();

            assert_eq!(st_s.pos(), st_c.pos(), "{preset}/{attn:?}: position skew");
            assert_eq!(
                st_s.state_bytes(),
                st_c.state_bytes(),
                "{preset}/{attn:?}: state footprint skew"
            );

            let run = |st: &mut DecodeState| -> (Vec<f32>, Vec<i32>) {
                let mut sc = model::DecodeScratch::new();
                let mut first = Vec::new();
                let mut out = Vec::new();
                let mut tok = toks[l - 1];
                for s in 0..steps {
                    let logits =
                        bound.logits_step_scratch(&[tok], st, &pool, &mut sc).unwrap();
                    if s == 0 {
                        first = logits.to_vec();
                    }
                    tok = logits
                        .iter()
                        .enumerate()
                        .filter(|(_, x)| x.is_finite())
                        .max_by(|a, b| a.1.total_cmp(b.1).then(b.0.cmp(&a.0)))
                        .map(|(i, _)| i as i32)
                        .unwrap();
                    out.push(tok);
                }
                (first, out)
            };
            let (first_s, gen_s) = run(&mut st_s);
            let (first_c, gen_c) = run(&mut st_c);

            let d = first_s
                .iter()
                .zip(&first_c)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(d < TOL, "{preset}/{attn:?}: first logits diverge (max {d})");
            assert_eq!(gen_s, gen_c, "{preset}/{attn:?}: greedy continuations diverge");
            #[cfg(not(feature = "simd"))]
            if attn == AttnKind::Softmax {
                // same kernels, same accumulation order ⇒ same bits
                assert_eq!(first_s, first_c, "{preset}: softmax prefill must be exact");
            }
        }
    }
}

/// The chunk length is a throughput knob, not a semantics knob: sweeping it
/// (including one chunk larger than the whole prompt, and a ragged tail)
/// must leave the post-prefill logits within rounding of each other, with
/// the prompt batched over two sequences.
#[test]
fn chunked_prefill_is_chunk_length_invariant() {
    let pool = ThreadPool::new(2);
    for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
        let cfg = LmConfig::tiny(attn);
        let params = param_state(&cfg, 17);
        let refs: Vec<&Tensor> = params.iter().collect();
        let bound = model::DecodeModel::bind(&cfg, &refs).unwrap();
        let l = cfg.n_ctx - 10; // not a multiple of 16: exercises the tail
        let toks: Vec<i32> =
            (0..2 * l).map(|i| ((i * 31 + 7) % cfg.vocab) as i32).collect();
        let mut outs = Vec::new();
        for chunk in [16usize, 128] {
            let mut st = DecodeState::new(&cfg, 2).unwrap();
            let mut psc = model::PrefillScratch::new();
            bound.prefill_chunked_with(chunk, &toks, &mut st, &pool, &mut psc).unwrap();
            assert_eq!(st.pos(), l, "{attn:?}/chunk={chunk}");
            outs.push(model::logits_step(&cfg, &refs, &[1, 2], &mut st, &pool).unwrap());
        }
        let d = outs[0]
            .iter()
            .zip(&outs[1])
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(d < TOL, "{attn:?}: chunk length changed the logits (max {d})");
    }
}

/// Quantized chunked prefill requantizes each layer's state once per window
/// instead of once per token, so it is NOT bit-identical to the serial
/// route — but it must stay within the same tolerance band the step-vs-full
/// parity suite grants bf16/int8 state storage.
#[test]
fn quantized_chunked_prefill_agrees_with_serial_route() {
    use repro::native::model::{Precision, QuantModel};
    let pool = ThreadPool::new(2);
    let tol = 0.75f32;
    for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
        for prec in [Precision::Bf16, Precision::Int8] {
            let cfg = LmConfig::tiny(attn);
            let params = param_state(&cfg, 19);
            let refs: Vec<&Tensor> = params.iter().collect();
            let qm = QuantModel::from_params(&cfg, &refs, prec).unwrap();
            let run_cfg = *qm.cfg();
            let bound = model::DecodeModel::bind_quantized(&qm).unwrap();
            let l = 40usize;
            let toks: Vec<i32> =
                (0..l).map(|i| ((i * 31 + 7) % cfg.vocab) as i32).collect();

            let mut st_s = DecodeState::new(&run_cfg, 1).unwrap();
            let mut dsc = model::DecodeScratch::new();
            for &t in &toks {
                bound.prefill_step_scratch(&[t], &mut st_s, &pool, &mut dsc).unwrap();
            }
            let a = bound.logits_step(&[3], &mut st_s, &pool).unwrap();

            let mut st_c = DecodeState::new(&run_cfg, 1).unwrap();
            let mut psc = model::PrefillScratch::new();
            bound.prefill_chunked(&toks, &mut st_c, &pool, &mut psc).unwrap();
            assert_eq!(st_s.pos(), st_c.pos());
            let b = bound.logits_step(&[3], &mut st_c, &pool).unwrap();

            let d =
                a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
            assert!(
                d < tol && a.iter().all(|x| x.is_finite()),
                "{attn:?}/{prec}: quantized routes diverge (max {d})"
            );
        }
    }
}

/// Greedy decoding from the same state must emit identical token ids on a
/// 1-thread and a many-thread pool (the pool's task decomposition is
/// worker-count independent).
#[test]
fn greedy_generation_is_thread_count_invariant() {
    for attn in [AttnKind::Ours, AttnKind::Softmax] {
        let cfg = LmConfig::tiny(attn);
        let params = param_state(&cfg, 3);
        let refs: Vec<&Tensor> = params.iter().collect();
        let run = |threads: usize| -> Vec<i32> {
            let pool = ThreadPool::new(threads);
            let mut st = DecodeState::new(&cfg, 1).unwrap();
            let mut out = Vec::new();
            let mut tok = 1i32;
            for _ in 0..24 {
                let logits = model::logits_step(&cfg, &refs, &[tok], &mut st, &pool).unwrap();
                tok = logits
                    .iter()
                    .enumerate()
                    .filter(|(_, x)| x.is_finite())
                    .max_by(|a, b| a.1.total_cmp(b.1).then(b.0.cmp(&a.0)))
                    .map(|(i, _)| i as i32)
                    .unwrap();
                out.push(tok);
            }
            out
        };
        assert_eq!(run(1), run(4), "{attn:?}: greedy decode depends on thread count");
    }
}

/// The memory contract the paper's inference claim rests on: the linear
/// variants decode with a state that never grows, softmax's KV cache grows
/// linearly in the decoded length.
#[test]
fn state_bytes_constant_for_linear_growing_for_softmax() {
    let pool = ThreadPool::new(2);
    let steps = 16;
    let mut footprints = std::collections::HashMap::new();
    for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
        let cfg = LmConfig::tiny(attn);
        let params = param_state(&cfg, 5);
        let refs: Vec<&Tensor> = params.iter().collect();
        let mut st = DecodeState::new(&cfg, 2).unwrap();
        let mut bytes = Vec::new();
        for t in 0..steps {
            model::logits_step(&cfg, &refs, &[(t % 7) as i32, (t % 5) as i32], &mut st, &pool)
                .unwrap();
            bytes.push(st.state_bytes());
        }
        footprints.insert(format!("{attn:?}"), bytes);
    }
    for kind in ["Ours", "Gated"] {
        let b = &footprints[kind];
        assert!(b.iter().all(|&x| x == b[0] && x > 0), "{kind}: state grew: {b:?}");
    }
    let sm = &footprints["Softmax"];
    assert_eq!(sm[0] * steps, sm[steps - 1], "softmax KV cache must grow linearly: {sm:?}");
    assert!(sm.windows(2).all(|w| w[1] > w[0]), "softmax KV cache must grow every step");
}

fn write_ckpt(dir: &std::path::Path, name: &str, tag: &str, layout: u32, cfg: &LmConfig) {
    let meta = CheckpointMeta {
        artifact_tag: tag.to_string(),
        step: 1,
        loss: 1.5,
        seed: 0,
        layout,
    };
    Checkpoint::write(dir.join(name), &meta, &cfg.init_state(0)).unwrap();
}

/// The full error chain a failed load produces (ModelSession is not Debug,
/// so `unwrap_err` is unavailable).
fn load_err(path: std::path::PathBuf) -> String {
    match ModelSession::load(&path) {
        Ok(_) => panic!("expected {path:?} to fail to load"),
        Err(e) => format!("{e:#}"),
    }
}

#[test]
fn checkpoint_load_hardening() {
    let dir = std::env::temp_dir().join("repro_infer_hardening");
    std::fs::create_dir_all(&dir).unwrap();
    let tiny = LmConfig::tiny(AttnKind::Ours);

    // missing file: a clear error, not a panic
    let err = load_err(dir.join("nope.ckpt"));
    assert!(err.contains("nope.ckpt"), "unhelpful error: {err}");

    // pre-refactor layout-v1 checkpoint: rejected by the layout guard
    write_ckpt(&dir, "v1.ckpt", "lm_tiny_ours", 1, &tiny);
    let err = load_err(dir.join("v1.ckpt"));
    assert!(err.contains("layout v1"), "unhelpful error: {err}");

    // a tag that is not an LM artifact
    write_ckpt(&dir, "tag.ckpt", "layer_ours_fwd", PARAM_LAYOUT_VERSION, &tiny);
    let err = load_err(dir.join("tag.ckpt"));
    assert!(err.contains("not an LM tag"), "unhelpful error: {err}");

    // an unknown preset inside an otherwise well-formed tag
    write_ckpt(&dir, "preset.ckpt", "lm_huge_ours", PARAM_LAYOUT_VERSION, &tiny);
    let err = load_err(dir.join("preset.ckpt"));
    assert!(err.contains("unknown LM preset"), "unhelpful error: {err}");

    // tag/state mismatch: a small tag over tiny-shaped state must not load
    write_ckpt(&dir, "mismatch.ckpt", "lm_small_ours", PARAM_LAYOUT_VERSION, &tiny);
    let err = load_err(dir.join("mismatch.ckpt"));
    assert!(err.contains("does not match its tag"), "unhelpful error: {err}");
}

#[test]
fn generate_is_deterministic_and_respects_the_window() {
    let dir = std::env::temp_dir().join("repro_infer_generate");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = LmConfig::tiny(AttnKind::Ours);
    write_ckpt(&dir, "ok.ckpt", "lm_tiny_ours", PARAM_LAYOUT_VERSION, &cfg);

    let session = ModelSession::load(dir.join("ok.ckpt")).unwrap();
    let req = GenRequest {
        prompt: "the ".to_string(),
        max_new: 16,
        mode: SampleMode::TopK { k: 8, temperature: 1.0 },
        seed: 42,
        samples: 2,
        ..GenRequest::default()
    };
    let a = session.generate(&req).unwrap();
    assert_eq!(a.texts.len(), 2);
    assert_eq!(a.new_tokens, 16);
    assert_eq!(a.prompt_tokens, 4);
    assert!(a.state_bytes > 0);

    // fixed seed ⇒ identical output, across a fresh session
    let b = ModelSession::load(dir.join("ok.ckpt")).unwrap().generate(&req).unwrap();
    assert_eq!(a.token_ids, b.token_ids);
    assert_eq!(a.texts, b.texts);

    // a prompt longer than the window is truncated; max_new is clamped
    let long = GenRequest {
        prompt: "x".repeat(200),
        max_new: 50,
        mode: SampleMode::Greedy,
        seed: 0,
        samples: 1,
        ..GenRequest::default()
    };
    let out = session.generate(&long).unwrap();
    assert_eq!(out.prompt_tokens, cfg.n_ctx - 1);
    assert_eq!(out.new_tokens, 1);

    // an empty prompt is a clear error
    let empty = GenRequest { prompt: String::new(), ..GenRequest::default() };
    assert!(session.generate(&empty).is_err());
}

#[test]
fn serve_loop_answers_requests_and_survives_garbage() {
    let dir = std::env::temp_dir().join("repro_infer_serve");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = LmConfig::tiny(AttnKind::Ours);
    write_ckpt(&dir, "ok.ckpt", "lm_tiny_ours", PARAM_LAYOUT_VERSION, &cfg);
    let session = ModelSession::load(dir.join("ok.ckpt")).unwrap();

    let input = concat!(
        "{\"id\": 1, \"prompt\": \"the \", \"max_new\": 4}\n",
        "\n",
        "{\"id\": 2, \"prompt\": \"a \", \"max_new\": 4, \"mode\": \"sample\", \
         \"top_k\": 8, \"seed\": \"18446744073709551615\"}\n",
        "this is not json\n",
        "{\"id\": 4, \"prompt\": \"b \", \"max_new\": 2, \"samples\": 2}\n",
        "{\"id\": 5, \"prompt\": 3}\n",
        "{\"id\": 6, \"prompt\": \"c \", \"samples\": 100000000}\n",
        "{\"id\": 7, \"prompt\": \"d \", \"temperature\": \"0.9\"}\n",
    );
    let mut out = Vec::new();
    let stats = serve_loop(&session, Cursor::new(input), &mut out, 64).unwrap();
    assert_eq!(stats.requests, 7);
    assert_eq!(stats.errors, 4);

    let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().lines().collect();
    assert_eq!(lines.len(), 7);
    let r1 = Json::parse(lines[0]).unwrap();
    assert_eq!(r1.get("id").and_then(Json::as_usize), Some(1));
    assert_eq!(r1.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(r1.get("new_tokens").and_then(Json::as_usize), Some(4));
    assert!(r1.get("text").and_then(Json::as_str).is_some());
    assert!(r1.get("tokens_per_s").and_then(Json::as_f64).is_some());
    assert!(r1.get("state_bytes").and_then(Json::as_usize).unwrap() > 0);

    let bad = Json::parse(lines[2]).unwrap();
    assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));
    assert!(bad.get("error").and_then(Json::as_str).is_some());

    // a u64 seed above 2^53, passed as a decimal string, is accepted
    let r2 = Json::parse(lines[1]).unwrap();
    assert_eq!(r2.get("ok").and_then(Json::as_bool), Some(true));

    let r4 = Json::parse(lines[3]).unwrap();
    assert_eq!(r4.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(r4.get("texts").and_then(Json::as_arr).map(<[Json]>::len), Some(2));

    // valid JSON with a bad field still echoes the request id
    let r5 = Json::parse(lines[4]).unwrap();
    assert_eq!(r5.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(r5.get("id").and_then(Json::as_usize), Some(5));
    assert!(r5.get("error").and_then(Json::as_str).unwrap().contains("prompt"));

    // an absurd batch size answers an error (never aborts the warm server)
    let r6 = Json::parse(lines[5]).unwrap();
    assert_eq!(r6.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(r6.get("id").and_then(Json::as_usize), Some(6));
    assert!(r6.get("error").and_then(Json::as_str).unwrap().contains("samples"));

    // wrong-typed sampling knobs are rejected, not silently defaulted
    let r7 = Json::parse(lines[6]).unwrap();
    assert_eq!(r7.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(r7.get("id").and_then(Json::as_usize), Some(7));
    assert!(r7.get("error").and_then(Json::as_str).unwrap().contains("temperature"));

    // identical greedy requests must produce identical responses (warm
    // session state does not leak between requests)
    let rerun = "{\"id\": 1, \"prompt\": \"the \", \"max_new\": 4}\n";
    let mut out2 = Vec::new();
    serve_loop(&session, Cursor::new(rerun), &mut out2, 64).unwrap();
    let a = Json::parse(std::str::from_utf8(&out2).unwrap().trim()).unwrap();
    assert_eq!(
        a.get("text").and_then(Json::as_str),
        r1.get("text").and_then(Json::as_str)
    );
}

/// The tokenizer a checkpoint implies must be reconstructible from
/// `(vocab, seed)` alone — exactly what the trainer built. The trainer and
/// inference now share `ByteTokenizer::for_artifact`, so the merge table
/// depends only on (vocab, seed) — never on this run's `corpus_bytes` (a
/// custom-corpus run used to silently imply an unreconstructible
/// tokenizer). This pins for_artifact against the historical slice-of-the-
/// training-corpus construction on the default corpus size.
#[test]
fn artifact_tokenizer_matches_trainer_construction() {
    use repro::data::{merge_train_slice, ByteTokenizer, CorpusConfig, CorpusGenerator};

    // the pre-fix trainer construction: full preset-sized corpus, merges on
    // the 100k-char slice — must coincide with the seed-keyed canonical form
    let corpus = CorpusGenerator::new(CorpusConfig {
        seed: 0,
        target_bytes: 1 << 20,
        ..Default::default()
    })
    .generate();
    let trainer_tok = ByteTokenizer::train(merge_train_slice(&corpus), 512).unwrap();

    // what both the trainer and inference do now
    let infer_tok = ByteTokenizer::for_artifact(512, 0).unwrap();

    assert_eq!(infer_tok.n_merges(), trainer_tok.n_merges());
    let sample = "the ancient harbor of bekoto3 is vasoli. 12 + 7 = 19.";
    assert_eq!(infer_tok.encode(sample), trainer_tok.encode(sample));
    assert_eq!(infer_tok.decode(&infer_tok.encode(sample)).unwrap(), sample);

    // and it is corpus-size independent by construction: two calls agree
    // regardless of any run-level corpus override
    let again = ByteTokenizer::for_artifact(512, 0).unwrap();
    assert_eq!(again.encode(sample), infer_tok.encode(sample));
}
