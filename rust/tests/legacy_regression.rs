//! Regression pin: the block-structured model, configured as
//! [`LmConfig::legacy_tiny`] (1 layer, 1 head, no LayerNorm, no MLP), must
//! reproduce the pre-refactor hand-unrolled model's loss trajectory and
//! parameter updates exactly.
//!
//! The oracle below is the pre-refactor `model.rs` forward/backward/Adam,
//! carried over verbatim (modulo plumbing) from commit a351c70 so the
//! comparison survives even though the original code path is gone. Both
//! sides share the same kernels, GEMM wrappers, and init, so the
//! trajectories must agree to f32 round-off (the block path adds only
//! layout-identity head reshapes).

// Too slow under the Miri interpreter (and process-spawning tests cannot
// run there at all) -- the Miri lane drives tests/miri_parity.rs instead.
#![cfg(not(miri))]

use repro::native::gemm;
use repro::native::kernels::{la_scan_bwd, la_scan_fwd, softmax_bwd, softmax_fwd, LayerShape};
use repro::native::model::{self, AttnKind, LmConfig};
use repro::native::pool::ThreadPool;
use repro::runtime::Tensor;

const EPS: f32 = 1e-6;
const GATED_DECAY: f32 = 0.95;

// --- the pre-refactor single-layer model, kept as the oracle -----------------

struct OldParams {
    wte: Vec<f32>,
    wpe: Vec<f32>,
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    wu: Vec<f32>,
    bu: Vec<f32>,
}

#[allow(clippy::too_many_arguments)]
fn matmul(
    pool: &ThreadPool,
    x: &[f32],
    w: &[f32],
    rows: usize,
    cin: usize,
    cout: usize,
    out: &mut [f32],
) {
    gemm::par_gemm_nn(pool, x, w, rows, cin, cout, out);
}

#[allow(clippy::too_many_arguments)]
fn matmul_dx(
    pool: &ThreadPool,
    dout: &[f32],
    w: &[f32],
    rows: usize,
    cin: usize,
    cout: usize,
    dx: &mut [f32],
) {
    gemm::par_gemm_nt(pool, dout, w, rows, cout, cin, dx);
}

#[allow(clippy::too_many_arguments)]
fn matmul_dw(
    pool: &ThreadPool,
    x: &[f32],
    dout: &[f32],
    rows: usize,
    cin: usize,
    cout: usize,
    dw: &mut [f32],
) {
    gemm::par_gemm_tn(pool, x, dout, cin, rows, cout, dw);
}

fn elu1(x: f32) -> f32 {
    if x > 0.0 {
        x + 1.0
    } else {
        x.exp()
    }
}

fn elu1_grad(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else {
        x.exp()
    }
}

struct OldCache {
    h0: Vec<f32>,
    qp: Vec<f32>,
    kp: Vec<f32>,
    vp: Vec<f32>,
    a: Vec<f32>,
    fq: Vec<f32>,
    fk: Vec<f32>,
    vext: Vec<f32>,
    u: Vec<f32>,
    h1: Vec<f32>,
}

fn attn_gamma(kind: AttnKind) -> f32 {
    match kind {
        AttnKind::Gated => GATED_DECAY,
        _ => 1.0,
    }
}

fn old_forward(
    cfg: &LmConfig,
    p: &OldParams,
    x: &[i32],
    pool: &ThreadPool,
) -> (Vec<f32>, OldCache) {
    let (bsz, l, d, v) = (cfg.batch, cfg.n_ctx, cfg.d_model, cfg.vocab);
    let rows = bsz * l;
    let mut h0 = vec![0.0f32; rows * d];
    for (r, &tok) in x.iter().enumerate() {
        let te = &p.wte[tok as usize * d..][..d];
        let pe = &p.wpe[(r % l) * d..][..d];
        let hr = &mut h0[r * d..][..d];
        for ((h, a), b) in hr.iter_mut().zip(te).zip(pe) {
            *h = a + b;
        }
    }
    let mut qp = vec![0.0f32; rows * d];
    let mut kp = vec![0.0f32; rows * d];
    let mut vp = vec![0.0f32; rows * d];
    matmul(pool, &h0, &p.wq, rows, d, d, &mut qp);
    matmul(pool, &h0, &p.wk, rows, d, d, &mut kp);
    matmul(pool, &h0, &p.wv, rows, d, d, &mut vp);

    let (a, fq, fk, vext, u) = match cfg.attn {
        AttnKind::Softmax => {
            let sh = LayerShape::cube(bsz, l, d);
            let scale = 1.0 / (d as f32).sqrt();
            let a = softmax_fwd(pool, &qp, &kp, &vp, sh, scale);
            (a, Vec::new(), Vec::new(), Vec::new(), Vec::new())
        }
        kind => {
            let gamma = attn_gamma(kind);
            let fq: Vec<f32> = qp.iter().map(|&x| elu1(x)).collect();
            let fk: Vec<f32> = kp.iter().map(|&x| elu1(x)).collect();
            let mut vext = vec![0.0f32; rows * (d + 1)];
            for r in 0..rows {
                vext[r * (d + 1)..][..d].copy_from_slice(&vp[r * d..][..d]);
                vext[r * (d + 1) + d] = 1.0;
            }
            let sh = LayerShape { bh: bsz, n: l, dk: d, dv: d + 1 };
            let u = la_scan_fwd(pool, &fq, &fk, &vext, sh, gamma);
            let mut a = vec![0.0f32; rows * d];
            for r in 0..rows {
                let ur = &u[r * (d + 1)..][..d + 1];
                let z = ur[d] + EPS;
                let ar = &mut a[r * d..][..d];
                for (ax, ux) in ar.iter_mut().zip(ur) {
                    *ax = ux / z;
                }
            }
            (a, fq, fk, vext, u)
        }
    };

    let mut h1 = h0.clone();
    matmul(pool, &a, &p.wo, rows, d, d, &mut h1);
    let mut logits = vec![0.0f32; rows * v];
    for r in 0..rows {
        logits[r * v..][..v].copy_from_slice(&p.bu);
    }
    matmul(pool, &h1, &p.wu, rows, d, v, &mut logits);
    (logits, OldCache { h0, qp, kp, vp, a, fq, fk, vext, u, h1 })
}

fn old_cross_entropy(logits: &[f32], y: &[i32], vocab: usize, dlogits: &mut [f32]) -> f32 {
    let rows = y.len();
    let inv_rows = 1.0 / rows as f32;
    let mut loss = 0.0f64;
    for (r, &target) in y.iter().enumerate() {
        let lr = &logits[r * vocab..][..vocab];
        let m = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for &x in lr {
            z += (x - m).exp();
        }
        loss += (m as f64) + (z as f64).ln() - lr[target as usize] as f64;
        let dr = &mut dlogits[r * vocab..][..vocab];
        let inv_z = 1.0 / z;
        for (dx, &x) in dr.iter_mut().zip(lr) {
            *dx = (x - m).exp() * inv_z * inv_rows;
        }
        dr[target as usize] -= inv_rows;
    }
    (loss / rows as f64) as f32
}

fn old_loss_and_grads(
    cfg: &LmConfig,
    p: &OldParams,
    x: &[i32],
    y: &[i32],
    pool: &ThreadPool,
) -> (f32, Vec<Vec<f32>>) {
    let (bsz, l, d, v) = (cfg.batch, cfg.n_ctx, cfg.d_model, cfg.vocab);
    let rows = bsz * l;
    let (logits, cache) = old_forward(cfg, p, x, pool);
    let mut dlogits = vec![0.0f32; rows * v];
    let loss = old_cross_entropy(&logits, y, v, &mut dlogits);

    let mut d_wte = vec![0.0f32; v * d];
    let mut d_wpe = vec![0.0f32; l * d];
    let mut d_wq = vec![0.0f32; d * d];
    let mut d_wk = vec![0.0f32; d * d];
    let mut d_wv = vec![0.0f32; d * d];
    let mut d_wo = vec![0.0f32; d * d];
    let mut d_wu = vec![0.0f32; d * v];
    let mut d_bu = vec![0.0f32; v];

    for r in 0..rows {
        let dr = &dlogits[r * v..][..v];
        for (db, g) in d_bu.iter_mut().zip(dr) {
            *db += g;
        }
    }
    matmul_dw(pool, &cache.h1, &dlogits, rows, d, v, &mut d_wu);
    let mut dh1 = vec![0.0f32; rows * d];
    matmul_dx(pool, &dlogits, &p.wu, rows, d, v, &mut dh1);

    let mut dh0 = dh1.clone();
    matmul_dw(pool, &cache.a, &dh1, rows, d, d, &mut d_wo);
    let mut da = vec![0.0f32; rows * d];
    matmul_dx(pool, &dh1, &p.wo, rows, d, d, &mut da);

    let (dqp, dkp, dvp) = match cfg.attn {
        AttnKind::Softmax => {
            let sh = LayerShape::cube(bsz, l, d);
            let scale = 1.0 / (d as f32).sqrt();
            softmax_bwd(pool, &cache.qp, &cache.kp, &cache.vp, &da, sh, scale)
        }
        kind => {
            let gamma = attn_gamma(kind);
            let mut du = vec![0.0f32; rows * (d + 1)];
            for r in 0..rows {
                let ur = &cache.u[r * (d + 1)..][..d + 1];
                let z = ur[d] + EPS;
                let dar = &da[r * d..][..d];
                let dur = &mut du[r * (d + 1)..][..d + 1];
                let mut dot = 0.0f32;
                for j in 0..d {
                    dur[j] = dar[j] / z;
                    dot += dar[j] * ur[j];
                }
                dur[d] = -dot / (z * z);
            }
            let sh = LayerShape { bh: bsz, n: l, dk: d, dv: d + 1 };
            let (dfq, dfk, dvext) =
                la_scan_bwd(pool, &cache.fq, &cache.fk, &cache.vext, &du, sh, gamma);
            let mut dqp = vec![0.0f32; rows * d];
            let mut dkp = vec![0.0f32; rows * d];
            let mut dvp = vec![0.0f32; rows * d];
            for i in 0..rows * d {
                dqp[i] = dfq[i] * elu1_grad(cache.qp[i]);
                dkp[i] = dfk[i] * elu1_grad(cache.kp[i]);
            }
            for r in 0..rows {
                dvp[r * d..][..d].copy_from_slice(&dvext[r * (d + 1)..][..d]);
            }
            (dqp, dkp, dvp)
        }
    };

    matmul_dw(pool, &cache.h0, &dqp, rows, d, d, &mut d_wq);
    matmul_dw(pool, &cache.h0, &dkp, rows, d, d, &mut d_wk);
    matmul_dw(pool, &cache.h0, &dvp, rows, d, d, &mut d_wv);
    matmul_dx(pool, &dqp, &p.wq, rows, d, d, &mut dh0);
    matmul_dx(pool, &dkp, &p.wk, rows, d, d, &mut dh0);
    matmul_dx(pool, &dvp, &p.wv, rows, d, d, &mut dh0);

    for (r, &tok) in x.iter().enumerate() {
        let g = &dh0[r * d..][..d];
        let te = &mut d_wte[tok as usize * d..][..d];
        for (dx, gx) in te.iter_mut().zip(g) {
            *dx += gx;
        }
        let pe = &mut d_wpe[(r % l) * d..][..d];
        for (dx, gx) in pe.iter_mut().zip(g) {
            *dx += gx;
        }
    }

    (loss, vec![d_wte, d_wpe, d_wq, d_wk, d_wv, d_wo, d_wu, d_bu])
}

/// One Adam step on a flat `Vec<Vec<f32>>` state, matching the in-model
/// optimizer constant-for-constant.
#[allow(clippy::too_many_arguments)]
fn old_train_step(
    cfg: &LmConfig,
    params: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    x: &[i32],
    y: &[i32],
    step: usize,
    pool: &ThreadPool,
) -> f32 {
    let p = OldParams {
        wte: params[0].clone(),
        wpe: params[1].clone(),
        wq: params[2].clone(),
        wk: params[3].clone(),
        wv: params[4].clone(),
        wo: params[5].clone(),
        wu: params[6].clone(),
        bu: params[7].clone(),
    };
    let (loss, grads) = old_loss_and_grads(cfg, &p, x, y, pool);
    let lr = cfg.lr_at(step);
    let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
    let t1 = (step + 1) as i32;
    let bc1 = 1.0 - b1.powi(t1);
    let bc2 = 1.0 - b2.powi(t1);
    for i in 0..8 {
        for j in 0..grads[i].len() {
            let g = grads[i][j];
            let m_new = b1 * m[i][j] + (1.0 - b1) * g;
            let v_new = b2 * v[i][j] + (1.0 - b2) * g * g;
            let mh = m_new / bc1;
            let vh = v_new / bc2;
            params[i][j] -= lr * mh / (vh.sqrt() + eps);
            m[i][j] = m_new;
            v[i][j] = v_new;
        }
    }
    loss
}

// --- the comparison -----------------------------------------------------------

fn tensor_data(t: &Tensor) -> Vec<f32> {
    match t {
        Tensor::F32 { data, .. } => data.clone(),
        _ => panic!("expected f32 tensor"),
    }
}

/// Structured batch (a short token cycle) — the same shape the historic
/// overfit test used, so the trajectory moves quickly and meaningfully.
fn cycle_tokens(cfg: &LmConfig) -> (Tensor, Vec<i32>, Vec<i32>) {
    let n = cfg.batch * (cfg.n_ctx + 1);
    let flat: Vec<i32> = (0..n).map(|i| (i % 17) as i32).collect();
    let toks = Tensor::i32(vec![cfg.batch, cfg.n_ctx + 1], flat.clone()).unwrap();
    let row = cfg.n_ctx + 1;
    let mut x = Vec::new();
    let mut y = Vec::new();
    for b in 0..cfg.batch {
        let r = &flat[b * row..][..row];
        x.extend_from_slice(&r[..cfg.n_ctx]);
        y.extend_from_slice(&r[1..]);
    }
    (toks, x, y)
}

#[test]
fn legacy_preset_matches_pre_refactor_trajectory() {
    const STEPS: usize = 8;
    const TOL: f32 = 1e-4;
    for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
        let cfg = LmConfig::legacy_tiny(attn);
        assert_eq!(cfg.n_param_arrays(), 8, "legacy layout changed");
        let pool = ThreadPool::new(2);
        let (toks, x, y) = cycle_tokens(&cfg);

        // oracle state: plain vectors, seeded by the same init
        let init = cfg.init_state(3);
        let mut old_p: Vec<Vec<f32>> = init[..8].iter().map(tensor_data).collect();
        let mut old_m: Vec<Vec<f32>> = init[8..16].iter().map(tensor_data).collect();
        let mut old_v: Vec<Vec<f32>> = init[16..24].iter().map(tensor_data).collect();

        // refactored state: driven through the public train_step
        let mut state = cfg.init_state(3);

        for step in 0..STEPS {
            let old_loss =
                old_train_step(&cfg, &mut old_p, &mut old_m, &mut old_v, &x, &y, step, &pool);
            let refs: Vec<&Tensor> = state.iter().collect();
            let out = model::train_step(&cfg, &refs, &toks, step as i64, &pool).unwrap();
            let new_loss = out[0].scalar().unwrap();
            assert!(
                (old_loss - new_loss).abs() < TOL,
                "{attn:?} step {step}: oracle loss {old_loss} vs refactored {new_loss}"
            );
            // out = [loss, grad_norm] ++ state' (the legacy preset runs with
            // weight_decay = clip_norm = 0, so the trajectory is unchanged)
            state = out[2..].to_vec();
        }

        // final parameters agree array-by-array
        for (i, old) in old_p.iter().enumerate() {
            let new = tensor_data(&state[i]);
            let worst = old
                .iter()
                .zip(&new)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(worst < TOL, "{attn:?} param array {i}: max abs diff {worst}");
        }
    }
}
