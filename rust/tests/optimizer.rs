//! AdamW optimizer tests: bit-exact parity of the in-place (owned-state)
//! step against the preserved rebuild step, global grad-norm clipping, and
//! decoupled weight decay.

// Too slow under the Miri interpreter (and process-spawning tests cannot
// run there at all) -- the Miri lane drives tests/miri_parity.rs instead.
#![cfg(not(miri))]

use repro::native::model::{self, AttnKind, LmConfig};
use repro::native::pool::ThreadPool;
use repro::runtime::Tensor;

fn pool() -> ThreadPool {
    ThreadPool::new(4)
}

fn cycle_tokens(cfg: &LmConfig) -> Tensor {
    let n = cfg.batch * (cfg.n_ctx + 1);
    Tensor::i32(
        vec![cfg.batch, cfg.n_ctx + 1],
        (0..n).map(|i| (i % 17) as i32).collect(),
    )
    .unwrap()
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.as_f32().unwrap().iter().map(|x| x.to_bits()).collect()
}

/// Synthetic constant gradients matching the config's parameter shapes.
fn const_grads(cfg: &LmConfig, value: f32) -> Vec<Vec<f32>> {
    cfg.param_shapes()
        .iter()
        .map(|(_, s)| vec![value; s.iter().product()])
        .collect()
}

/// The tentpole invariant: several in-place AdamW steps reproduce the
/// preserved rebuild implementation bit for bit — losses, grad norms, and
/// every params/m/v buffer — with weight decay and clipping active.
#[test]
fn inplace_step_is_bit_exact_against_rebuild() {
    const STEPS: usize = 5;
    for attn in [AttnKind::Ours, AttnKind::Softmax] {
        let mut cfg = LmConfig::tiny(attn);
        // tighten the clip so the test exercises the rescale branch too
        cfg.clip_norm = 0.5;
        assert!(cfg.weight_decay > 0.0, "decay must be active for the parity to mean anything");
        let toks = cycle_tokens(&cfg);
        let pool = pool();

        let mut rebuilt = cfg.init_state(3);
        let mut inplace = cfg.init_state(3);
        for step in 0..STEPS {
            let refs: Vec<&Tensor> = rebuilt.iter().collect();
            let out = model::train_step(&cfg, &refs, &toks, step as i64, &pool).unwrap();
            let (loss_rb, norm_rb) = (out[0].scalar().unwrap(), out[1].scalar().unwrap());
            drop(refs);
            rebuilt = out[2..].to_vec();

            let (loss_ip, norm_ip) =
                model::train_step_mut(&cfg, &mut inplace, &toks, step as i64, &pool).unwrap();

            assert_eq!(
                loss_rb.to_bits(),
                loss_ip.to_bits(),
                "{attn:?} step {step}: loss diverged ({loss_rb} vs {loss_ip})"
            );
            assert_eq!(
                norm_rb.to_bits(),
                norm_ip.to_bits(),
                "{attn:?} step {step}: grad norm diverged"
            );
            assert_eq!(rebuilt.len(), inplace.len());
            for (i, (a, b)) in rebuilt.iter().zip(&inplace).enumerate() {
                assert_eq!(bits(a), bits(b), "{attn:?} step {step}: state array {i} diverged");
            }
        }
    }
}

/// Clipping: a synthetic huge gradient is rescaled to the clip threshold
/// before entering the moments — starting from zero moments, the post-step
/// global norm of `m` is exactly `(1 − β₁) · clip_norm`; the *reported*
/// norm stays pre-clip.
#[test]
fn global_norm_clipping_bounds_the_update() {
    let mut cfg = LmConfig::tiny(AttnKind::Ours);
    cfg.clip_norm = 1.0;
    cfg.weight_decay = 0.0;
    let mut state = cfg.init_state(0);
    let np = cfg.n_param_arrays();
    let grads = const_grads(&cfg, 1000.0);

    let reported = model::adamw_update_mut(&cfg, &mut state, &grads, 0, &pool()).unwrap();
    let expected = model::grad_global_norm(&grads);
    assert!(
        (reported - expected).abs() / expected < 1e-6,
        "reported norm must be pre-clip ({reported} vs {expected})"
    );
    assert!(reported > cfg.clip_norm as f32 * 100.0, "gradient must be huge for this test");

    // ‖m‖ = (1 − β₁) · ‖g_clipped‖ = 0.1 · clip_norm
    let m_sq: f64 = state[np..2 * np]
        .iter()
        .map(|t| t.as_f32().unwrap().iter().map(|&x| x as f64 * x as f64).sum::<f64>())
        .sum();
    let m_norm = m_sq.sqrt();
    assert!(
        (m_norm - 0.1 * cfg.clip_norm).abs() < 1e-4,
        "post-clip moment norm {m_norm}, want {}",
        0.1 * cfg.clip_norm
    );
    for t in &state[..np] {
        assert!(t.as_f32().unwrap().iter().all(|x| x.is_finite()));
    }
}

/// `clip_norm = 0` disables clipping entirely: the moments absorb the raw
/// gradient.
#[test]
fn zero_clip_norm_disables_clipping() {
    let mut cfg = LmConfig::tiny(AttnKind::Ours);
    cfg.clip_norm = 0.0;
    cfg.weight_decay = 0.0;
    let mut state = cfg.init_state(0);
    let np = cfg.n_param_arrays();
    let grads = const_grads(&cfg, 2.0);
    let norm = model::adamw_update_mut(&cfg, &mut state, &grads, 0, &pool()).unwrap();
    let m_sq: f64 = state[np..2 * np]
        .iter()
        .map(|t| t.as_f32().unwrap().iter().map(|&x| x as f64 * x as f64).sum::<f64>())
        .sum();
    let m_norm = m_sq.sqrt() as f32;
    assert!(
        (m_norm - 0.1 * norm).abs() / (0.1 * norm) < 1e-5,
        "moments must hold the unclipped gradient ({m_norm} vs {})",
        0.1 * norm
    );
}

/// Decoupled weight decay: with zero gradients, the moments stay exactly
/// zero while ≥2-D parameters shrink by `lr·wd` — and 1-D parameters
/// (biases, LayerNorm affines) are never decayed.
#[test]
fn weight_decay_is_decoupled_from_the_moments() {
    let mut cfg = LmConfig::tiny(AttnKind::Ours);
    cfg.weight_decay = 0.5;
    cfg.clip_norm = 0.0;
    let state0 = cfg.init_state(1);
    let mut state = state0.clone();
    let np = cfg.n_param_arrays();
    let grads = const_grads(&cfg, 0.0);

    let norm = model::adamw_update_mut(&cfg, &mut state, &grads, 0, &pool()).unwrap();
    assert_eq!(norm, 0.0);

    let shapes = cfg.param_shapes();
    let lr_wd = cfg.lr_at(0) * cfg.weight_decay as f32;
    for i in 0..np {
        let before = state0[i].as_f32().unwrap();
        let after = state[i].as_f32().unwrap();
        let (name, shape) = &shapes[i];
        if shape.len() >= 2 {
            // p' = p·(1 − lr·wd), applied directly to the parameter
            for (j, (&b, &a)) in before.iter().zip(after).enumerate() {
                let want = b - lr_wd * b;
                assert!(
                    (a - want).abs() <= 1e-7 + want.abs() * 1e-6,
                    "{name}[{j}]: decayed {b} → {a}, want {want}"
                );
            }
        } else {
            assert_eq!(before, after, "{name}: 1-D params must not decay");
        }
    }
    // moments never see the decay (they only integrate gradients, here zero)
    for (i, t) in state[np..].iter().enumerate() {
        assert!(
            t.as_f32().unwrap().iter().all(|&x| x == 0.0),
            "moment array {i} picked up weight decay"
        );
    }
}

/// The in-place update is invariant to the pool's thread count (tasks are
/// partitioned per parameter array, arithmetic is element-local).
#[test]
fn inplace_update_is_thread_count_invariant() {
    let cfg = LmConfig::tiny(AttnKind::Ours);
    let grads = const_grads(&cfg, 0.01);
    let mut s1 = cfg.init_state(9);
    let mut s4 = cfg.init_state(9);
    let n1 = model::adamw_update_mut(&cfg, &mut s1, &grads, 0, &ThreadPool::new(1)).unwrap();
    let n4 = model::adamw_update_mut(&cfg, &mut s4, &grads, 0, &ThreadPool::new(4)).unwrap();
    assert_eq!(n1.to_bits(), n4.to_bits());
    for (a, b) in s1.iter().zip(&s4) {
        assert_eq!(bits(a), bits(b));
    }
}
