//! Finite-difference check of the multi-layer analytic backward pass: for
//! every `AttnKind`, perturb a strided sample of every parameter array and
//! compare the central-difference slope against `model::loss_and_grads`.
//!
//! Shapes are kept tiny (the check is O(params × forward)); the step size
//! and tolerance are set for f32 forwards — central differencing at
//! `h = 5e-3` keeps truncation ~1e-3 relative while staying well above the
//! ~1e-6 f32 evaluation noise.

// Too slow under the Miri interpreter (and process-spawning tests cannot
// run there at all) -- the Miri lane drives tests/miri_parity.rs instead.
#![cfg(not(miri))]

use repro::native::model::{self, AttnKind, LmConfig, Precision};
use repro::native::pool::ThreadPool;
use repro::runtime::Tensor;

const H: f32 = 5e-3;
/// |numeric − analytic| must stay below ABS_TOL + REL_TOL·|numeric|.
const ABS_TOL: f32 = 2e-3;
const REL_TOL: f32 = 2e-2;
/// Strided sample size per parameter array.
const SAMPLES_PER_ARRAY: usize = 9;

/// A deliberately awkward little config: multiple layers and heads, an MLP,
/// LayerNorms, and a vocab that is not a power of two.
fn deep_cfg(attn: AttnKind) -> LmConfig {
    LmConfig {
        vocab: 13,
        n_ctx: 5,
        d_model: 8,
        n_layer: 2,
        n_head: 2,
        d_ff: 12,
        layernorm: true,
        batch: 2,
        attn,
        lr_max: 1e-2,
        lr_min: 1e-3,
        warmup_steps: 2,
        total_steps: 10,
        weight_decay: 0.0,
        clip_norm: 0.0,
        precision: Precision::F32,
    }
}

fn tokens_for(cfg: &LmConfig, seed: u64) -> Tensor {
    let mut rng = repro::data::rng::SplitMix64::new(seed);
    let n = cfg.batch * (cfg.n_ctx + 1);
    Tensor::i32(
        vec![cfg.batch, cfg.n_ctx + 1],
        (0..n).map(|_| rng.below(cfg.vocab) as i32).collect(),
    )
    .unwrap()
}

/// Check every parameter array of `cfg` (strided entries) and return the
/// worst (error, tolerance, label) triple.
fn run_grad_check(cfg: &LmConfig, tag: &str) {
    cfg.validate().unwrap();
    let pool = ThreadPool::new(2);
    let state = cfg.init_state(0xC0FFEE);
    let np = cfg.n_param_arrays();
    let toks = tokens_for(cfg, 42);

    let refs: Vec<&Tensor> = state[..np].iter().collect();
    let (_loss, grads) = model::loss_and_grads(cfg, &refs, &toks, &pool).unwrap();
    assert_eq!(grads.len(), np, "{tag}: gradient count");

    // mutable copy of the params we can poke entries of
    let mut params: Vec<Tensor> = state[..np].to_vec();
    let shapes = cfg.param_shapes();
    let mut checked = 0usize;
    for ai in 0..np {
        let len = grads[ai].len();
        let stride = (len / SAMPLES_PER_ARRAY).max(1);
        let mut j = 0;
        while j < len {
            let eval_at = |params: &[Tensor]| -> f32 {
                let refs: Vec<&Tensor> = params.iter().collect();
                model::eval_loss(cfg, &refs, &toks, &pool).unwrap()
            };
            let orig = match &params[ai] {
                Tensor::F32 { data, .. } => data[j],
                _ => unreachable!("params are f32"),
            };
            let set = |params: &mut [Tensor], v: f32| {
                if let Tensor::F32 { data, .. } = &mut params[ai] {
                    data[j] = v;
                }
            };
            set(&mut params, orig + H);
            let lp = eval_at(&params);
            set(&mut params, orig - H);
            let lm = eval_at(&params);
            set(&mut params, orig);
            let numeric = (lp - lm) / (2.0 * H);
            let analytic = grads[ai][j];
            let tol = ABS_TOL + REL_TOL * numeric.abs();
            assert!(
                (numeric - analytic).abs() < tol,
                "{tag}: {}[{j}] numeric {numeric} vs analytic {analytic} (tol {tol})",
                shapes[ai].0
            );
            checked += 1;
            j += stride;
        }
    }
    assert!(checked >= np * 2, "{tag}: only {checked} entries checked");
}

#[test]
fn grad_check_ours_deep() {
    run_grad_check(&deep_cfg(AttnKind::Ours), "ours");
}

#[test]
fn grad_check_gated_deep() {
    run_grad_check(&deep_cfg(AttnKind::Gated), "gated");
}

#[test]
fn grad_check_softmax_deep() {
    run_grad_check(&deep_cfg(AttnKind::Softmax), "softmax");
}

/// The legacy architecture exercises the no-LayerNorm / no-MLP backward
/// branches (gradients accumulate straight into the residual stream).
#[test]
fn grad_check_legacy_architecture() {
    let cfg = LmConfig {
        vocab: 13,
        n_ctx: 5,
        d_model: 8,
        n_layer: 1,
        n_head: 1,
        d_ff: 0,
        layernorm: false,
        batch: 2,
        attn: AttnKind::Ours,
        lr_max: 1e-2,
        lr_min: 1e-3,
        warmup_steps: 2,
        total_steps: 10,
        weight_decay: 0.0,
        clip_norm: 0.0,
        precision: Precision::F32,
    };
    run_grad_check(&cfg, "legacy");
}
