//! Parity of the parallel/tiled kernels against the scalar single-thread
//! reference path ([`repro::native::kernels::reference`]) at the (256, 32)
//! contract shape, for all five kernel families — state scan, chunkwise,
//! quadratic, softmax, and the GEMM microkernels — plus thread-count
//! invariance: the task decomposition is fixed, so results must not depend
//! on how many workers execute it.

// Too slow under the Miri interpreter (and process-spawning tests cannot
// run there at all) -- the Miri lane drives tests/miri_parity.rs instead.
#![cfg(not(miri))]

use repro::native::gemm;
use repro::native::kernels::{self, reference, LayerShape};
use repro::native::pool::ThreadPool;
use repro::runtime::Tensor;

const N: usize = 256;
const D: usize = 32;
const BH: usize = 4;
const CHUNK: usize = 48; // deliberately not a divisor of N: exercises the ragged tail
const TOL: f32 = 1e-4;
const INVARIANCE_TOL: f32 = 1e-5;

fn flat_randn(n: usize, seed: u64) -> Vec<f32> {
    match Tensor::randn(vec![n], seed) {
        Tensor::F32 { data, .. } => data,
        _ => unreachable!(),
    }
}

/// q/k drawn as unit rows (paper §3.3 normalization), v/go plain normal.
fn layer_inputs(sh: LayerShape, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut q = Tensor::randn(vec![sh.bh, sh.n, sh.dk], seed);
    let mut k = Tensor::randn(vec![sh.bh, sh.n, sh.dk], seed + 1);
    q.normalize_rows();
    k.normalize_rows();
    let v = flat_randn(sh.bh * sh.n * sh.dv, seed + 2);
    let go = flat_randn(sh.bh * sh.n * sh.dv, seed + 3);
    let q = match q {
        Tensor::F32 { data, .. } => data,
        _ => unreachable!(),
    };
    let k = match k {
        Tensor::F32 { data, .. } => data,
        _ => unreachable!(),
    };
    (q, k, v, go)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn assert_close(name: &str, got: &[f32], want: &[f32], tol: f32) {
    let d = max_abs_diff(got, want);
    assert!(d < tol, "{name}: max abs diff {d} (tol {tol})");
}

#[test]
fn scan_parallel_matches_reference() {
    let sh = LayerShape::cube(BH, N, D);
    let (q, k, v, go) = layer_inputs(sh, 0x51);
    let pool = ThreadPool::new(4);
    for gamma in [1.0f32, 0.95] {
        let o = kernels::la_scan_fwd(&pool, &q, &k, &v, sh, gamma);
        let o_ref = reference::la_scan_fwd(&q, &k, &v, sh, gamma);
        assert_close("scan fwd", &o, &o_ref, TOL);
        let (dq, dk, dv) = kernels::la_scan_bwd(&pool, &q, &k, &v, &go, sh, gamma);
        let (rq, rk, rv) = reference::la_scan_bwd(&q, &k, &v, &go, sh, gamma);
        assert_close("scan dq", &dq, &rq, TOL);
        assert_close("scan dk", &dk, &rk, TOL);
        assert_close("scan dv", &dv, &rv, TOL);
    }
}

#[test]
fn chunk_parallel_matches_reference() {
    let sh = LayerShape::cube(BH, N, D);
    let (q, k, v, go) = layer_inputs(sh, 0x52);
    let pool = ThreadPool::new(4);
    for chunk in [CHUNK, 64, N + 7] {
        let o = kernels::la_chunk_fwd(&pool, &q, &k, &v, sh, chunk);
        let o_ref = reference::la_chunk_fwd(&q, &k, &v, sh, chunk);
        assert_close(&format!("chunk fwd C={chunk}"), &o, &o_ref, TOL);
        let (dq, dk, dv) = kernels::la_chunk_bwd(&pool, &q, &k, &v, &go, sh, chunk);
        let (rq, rk, rv) = reference::la_chunk_bwd(&q, &k, &v, &go, sh, chunk);
        assert_close(&format!("chunk dq C={chunk}"), &dq, &rq, TOL);
        assert_close(&format!("chunk dk C={chunk}"), &dk, &rk, TOL);
        assert_close(&format!("chunk dv C={chunk}"), &dv, &rv, TOL);
    }
}

#[test]
fn quadratic_parallel_matches_reference() {
    let sh = LayerShape::cube(BH, N, D);
    let (q, k, v, go) = layer_inputs(sh, 0x53);
    let pool = ThreadPool::new(4);
    let o = kernels::la_quadratic_fwd(&pool, &q, &k, &v, sh);
    let o_ref = reference::la_quadratic_fwd(&q, &k, &v, sh);
    assert_close("quadratic fwd", &o, &o_ref, TOL);
    let (dq, dk, dv) = kernels::la_quadratic_bwd(&pool, &q, &k, &v, &go, sh);
    let (rq, rk, rv) = reference::la_quadratic_bwd(&q, &k, &v, &go, sh);
    assert_close("quadratic dq", &dq, &rq, TOL);
    assert_close("quadratic dk", &dk, &rk, TOL);
    assert_close("quadratic dv", &dv, &rv, TOL);
}

#[test]
fn softmax_parallel_matches_reference() {
    let sh = LayerShape::cube(BH, N, D);
    let (q, k, v, go) = layer_inputs(sh, 0x54);
    let scale = 1.0 / (D as f32).sqrt();
    let pool = ThreadPool::new(4);
    let o = kernels::softmax_fwd(&pool, &q, &k, &v, sh, scale);
    let o_ref = reference::softmax_fwd(&q, &k, &v, sh, scale);
    assert_close("softmax fwd", &o, &o_ref, TOL);
    let (dq, dk, dv) = kernels::softmax_bwd(&pool, &q, &k, &v, &go, sh, scale);
    let (rq, rk, rv) = reference::softmax_bwd(&q, &k, &v, &go, sh, scale);
    assert_close("softmax dq", &dq, &rq, TOL);
    assert_close("softmax dk", &dk, &rk, TOL);
    assert_close("softmax dv", &dv, &rv, TOL);
}

#[test]
fn gemm_tiled_matches_naive() {
    // the fifth family: the microkernels every tiled path is built from
    let (m, k, n) = (37, D, 29);
    let a = flat_randn(m * k, 0x55);
    let b = flat_randn(k * n, 0x56);
    let mut naive = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            for j in 0..n {
                naive[i * n + j] += a[i * k + p] * b[p * n + j];
            }
        }
    }
    let mut tiled = vec![0.0f32; m * n];
    gemm::gemm_nn(&a, &b, m, k, n, &mut tiled);
    assert_close("gemm_nn", &tiled, &naive, TOL);

    // nt/tn against the same oracle via explicit transposes
    let mut bt = vec![0.0f32; n * k];
    for p in 0..k {
        for j in 0..n {
            bt[j * k + p] = b[p * n + j];
        }
    }
    let mut out_nt = vec![0.0f32; m * n];
    gemm::gemm_nt(&a, &bt, m, k, n, &mut out_nt);
    assert_close("gemm_nt", &out_nt, &naive, TOL);

    let mut at = vec![0.0f32; k * m];
    for i in 0..m {
        for p in 0..k {
            at[p * m + i] = a[i * k + p];
        }
    }
    let mut out_tn = vec![0.0f32; m * n];
    gemm::gemm_tn(&at, &b, m, k, n, &mut out_tn);
    assert_close("gemm_tn", &out_tn, &naive, TOL);
}

/// `RUST_PALLAS_THREADS=1` vs `=4` must agree: the per-task arithmetic is
/// fixed by the decomposition, independent of the worker count.
#[test]
fn thread_count_invariance() {
    let sh = LayerShape::cube(BH, N, D);
    let (q, k, v, go) = layer_inputs(sh, 0x57);
    let p1 = ThreadPool::new(1);
    let p4 = ThreadPool::new(4);

    let pairs: [(&str, Vec<f32>, Vec<f32>); 4] = [
        (
            "scan fwd",
            kernels::la_scan_fwd(&p1, &q, &k, &v, sh, 1.0),
            kernels::la_scan_fwd(&p4, &q, &k, &v, sh, 1.0),
        ),
        (
            "chunk fwd",
            kernels::la_chunk_fwd(&p1, &q, &k, &v, sh, CHUNK),
            kernels::la_chunk_fwd(&p4, &q, &k, &v, sh, CHUNK),
        ),
        (
            "quadratic fwd",
            kernels::la_quadratic_fwd(&p1, &q, &k, &v, sh),
            kernels::la_quadratic_fwd(&p4, &q, &k, &v, sh),
        ),
        (
            "softmax fwd",
            kernels::softmax_fwd(&p1, &q, &k, &v, sh, 0.25),
            kernels::softmax_fwd(&p4, &q, &k, &v, sh, 0.25),
        ),
    ];
    for (name, a, b) in &pairs {
        assert_close(name, a, b, INVARIANCE_TOL);
    }

    let (dq1, dk1, dv1) = kernels::la_chunk_bwd(&p1, &q, &k, &v, &go, sh, CHUNK);
    let (dq4, dk4, dv4) = kernels::la_chunk_bwd(&p4, &q, &k, &v, &go, sh, CHUNK);
    assert_close("chunk bwd dq", &dq1, &dq4, INVARIANCE_TOL);
    assert_close("chunk bwd dk", &dk1, &dk4, INVARIANCE_TOL);
    assert_close("chunk bwd dv", &dv1, &dv4, INVARIANCE_TOL);

    let (sq1, sk1, sv1) = kernels::la_scan_bwd(&p1, &q, &k, &v, &go, sh, 1.0);
    let (sq4, sk4, sv4) = kernels::la_scan_bwd(&p4, &q, &k, &v, &go, sh, 1.0);
    assert_close("scan bwd dq", &sq1, &sq4, INVARIANCE_TOL);
    assert_close("scan bwd dk", &sk1, &sk4, INVARIANCE_TOL);
    assert_close("scan bwd dv", &sv1, &sv4, INVARIANCE_TOL);
}

/// The executor path end-to-end: an engine over a 1-thread pool and one over
/// a 4-thread pool produce matching artifact outputs, and both match the
/// scalar-reference backend.
#[test]
fn backend_pools_agree_on_quickstart_artifact() {
    use repro::native::NativeBackend;
    use repro::runtime::Engine;

    let run = |backend: NativeBackend| -> Vec<f32> {
        let engine = Engine::with_backend(Box::new(backend)).unwrap();
        let exe = engine.load("quickstart_la_fwd").unwrap();
        let inputs: Vec<Tensor> = exe
            .meta
            .inputs
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let mut t = Tensor::randn(spec.shape.clone(), 0x99 + i as u64);
                if i < 2 {
                    t.normalize_rows();
                }
                t
            })
            .collect();
        let out = exe.run(&inputs).unwrap();
        match &out[0] {
            Tensor::F32 { data, .. } => data.clone(),
            _ => unreachable!(),
        }
    };
    let o1 = run(NativeBackend::with_pool(ThreadPool::new(1)));
    let o4 = run(NativeBackend::with_pool(ThreadPool::new(4)));
    let oref = run(NativeBackend::scalar_reference());
    assert_close("pool(1) vs pool(4)", &o1, &o4, INVARIANCE_TOL);
    assert_close("pool(4) vs scalar reference", &o4, &oref, TOL);
}
