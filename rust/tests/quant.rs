//! End-to-end tests of the low-precision path: quantize a full-precision
//! checkpoint on disk, load the layout-v3 artifact through the ordinary
//! inference session, and decode from it.
//!
//! The numeric primitives have unit tests in `native::quant`, and the
//! kernel/state parity lives in `tests/miri_parity.rs`; this file covers the
//! seams between them — `quantize_checkpoint` → `ModelSession::load` →
//! `generate`, plus the footprint claims the bench report makes.

// Heavier than a unit test and file-system bound — not a Miri target.
#![cfg(not(miri))]

use repro::coordinator::{Checkpoint, CheckpointMeta, PARAM_LAYOUT_VERSION};
use repro::infer::{quantize_checkpoint, GenRequest, ModelSession, SampleMode};
use repro::native::model::{AttnKind, LmConfig, Precision};

fn write_f32_ckpt(dir: &std::path::Path, name: &str, cfg: &LmConfig, seed: u64) {
    let meta = CheckpointMeta {
        artifact_tag: "lm_tiny_ours".to_string(),
        step: 1,
        loss: 1.5,
        seed,
        layout: PARAM_LAYOUT_VERSION,
    };
    Checkpoint::write(dir.join(name), &meta, &cfg.init_state(seed)).unwrap();
}

fn greedy(prompt: &str, max_new: usize) -> GenRequest {
    GenRequest {
        prompt: prompt.to_string(),
        max_new,
        mode: SampleMode::Greedy,
        seed: 0,
        samples: 1,
        ..GenRequest::default()
    }
}

#[test]
fn quantize_load_generate_roundtrip() {
    let dir = std::env::temp_dir().join("repro_quant_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = LmConfig::tiny(AttnKind::Ours);
    write_f32_ckpt(&dir, "f32.ckpt", &cfg, 11);

    let f32_sess = ModelSession::load(dir.join("f32.ckpt")).unwrap();
    let f32_out = f32_sess.generate(&greedy("the ", 12)).unwrap();

    for prec in [Precision::Bf16, Precision::Int8] {
        let qpath = dir.join(format!("{prec}.ckpt"));
        let outcome =
            quantize_checkpoint(dir.join("f32.ckpt"), &qpath, prec, 8).unwrap();
        assert_eq!(outcome.precision, prec);
        assert_eq!(outcome.check_tokens, 8);
        assert!(
            outcome.logit_max_abs_diff.is_finite() && outcome.logit_max_abs_diff >= 0.0,
            "probe diff: {}",
            outcome.logit_max_abs_diff
        );
        assert!(
            outcome.quant_param_bytes < outcome.f32_param_bytes,
            "{prec}: {} !< {}",
            outcome.quant_param_bytes,
            outcome.f32_param_bytes
        );
        if prec == Precision::Int8 {
            // the headline claim: ≥2× parameter-byte reduction (the GEMM
            // weights shrink 4×; embeddings/norms/biases stay f32)
            assert!(
                outcome.quant_param_bytes * 2 <= outcome.f32_param_bytes,
                "int8 shrink below 2×: {} vs {}",
                outcome.quant_param_bytes,
                outcome.f32_param_bytes
            );
        }

        // the quantized artifact loads through the SAME session entry point
        let sess = ModelSession::load(&qpath).unwrap();
        assert!(
            sess.summary().contains(prec.name()),
            "summary hides the precision: {}",
            sess.summary()
        );
        let a = sess.generate(&greedy("the ", 12)).unwrap();
        assert_eq!(a.new_tokens, 12);
        assert_eq!(a.texts.len(), 1);
        assert!(!a.texts[0].is_empty());

        // deterministic across a fresh load of the same artifact
        let b = ModelSession::load(&qpath).unwrap().generate(&greedy("the ", 12)).unwrap();
        assert_eq!(a.token_ids, b.token_ids);
        assert_eq!(a.texts, b.texts);

        // the decode-state footprint must shrink too (linear attention:
        // int8 carries ~1 byte/entry + per-row scales vs 4 bytes/entry)
        if prec == Precision::Int8 {
            assert!(
                a.state_bytes * 2 < f32_out.state_bytes,
                "int8 state {} vs f32 state {}",
                a.state_bytes,
                f32_out.state_bytes
            );
        }
    }
}

#[test]
fn quantizing_a_quantized_checkpoint_is_rejected() {
    let dir = std::env::temp_dir().join("repro_quant_requant");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = LmConfig::tiny(AttnKind::Ours);
    write_f32_ckpt(&dir, "f32.ckpt", &cfg, 3);

    let q = dir.join("int8.ckpt");
    quantize_checkpoint(dir.join("f32.ckpt"), &q, Precision::Int8, 0).unwrap();
    let err = quantize_checkpoint(&q, dir.join("int8_again.ckpt"), Precision::Int8, 0)
        .map(|_| ())
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("quantiz"), "unhelpful error: {msg}");
}

#[test]
fn probe_skip_still_quantizes() {
    // `check_tokens = 0` skips the logit probe entirely but must still
    // produce a loadable artifact
    let dir = std::env::temp_dir().join("repro_quant_noprobe");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = LmConfig::tiny(AttnKind::Ours);
    write_f32_ckpt(&dir, "f32.ckpt", &cfg, 5);

    let q = dir.join("bf16.ckpt");
    let outcome = quantize_checkpoint(dir.join("f32.ckpt"), &q, Precision::Bf16, 0).unwrap();
    assert_eq!(outcome.check_tokens, 0);
    assert_eq!(outcome.logit_max_abs_diff, 0.0);
    let sess = ModelSession::load(&q).unwrap();
    assert_eq!(sess.generate(&greedy("a ", 4)).unwrap().new_tokens, 4);
}
