//! BPE `ByteTokenizer` at vocabularies above the byte range — the load-bearing
//! path for the `small` LM preset (vocab 512): exact round-trips on realistic
//! generated corpora, id-range containment, and merge determinism across
//! corpus seeds.

// Too slow under the Miri interpreter (and process-spawning tests cannot
// run there at all) -- the Miri lane drives tests/miri_parity.rs instead.
#![cfg(not(miri))]

use repro::data::{ByteTokenizer, CorpusConfig, CorpusGenerator};

const VOCAB: usize = 512;

fn corpus(seed: u64) -> String {
    CorpusGenerator::new(CorpusConfig {
        seed,
        target_bytes: 80_000,
        ..Default::default()
    })
    .generate()
}

/// Prefix of `s` holding at most `n` chars, cut on a char boundary (the
/// corpus may contain multi-byte UTF-8).
fn char_prefix(s: &str, n: usize) -> &str {
    match s.char_indices().nth(n) {
        Some((i, _)) => &s[..i],
        None => s,
    }
}

#[test]
fn vocab512_roundtrips_training_and_unseen_text() {
    let text = corpus(0);
    let slice = char_prefix(&text, 40_000);
    let tok = ByteTokenizer::train(slice, VOCAB).unwrap();
    assert!(tok.n_merges() > 0, "an 80 KB corpus must yield merges");
    assert_eq!(tok.vocab_size(), VOCAB);

    // exact round-trip on the training slice, the full corpus, and text the
    // merges never saw (including multi-byte UTF-8)
    for probe in [slice, &text[..], "never seen: γ-decayed Ω-state 𝚽!"] {
        let ids = tok.encode(probe);
        assert!(
            ids.iter().all(|&i| i >= 0 && (i as usize) < VOCAB),
            "id out of range"
        );
        assert_eq!(tok.decode(&ids).unwrap(), probe);
    }

    // merges actually compress the training distribution
    let ids = tok.encode(&text);
    assert!(
        ids.len() < text.len(),
        "{} tokens !< {} bytes",
        ids.len(),
        text.len()
    );
    assert!(
        ids.iter().any(|&i| i >= 256),
        "no merged id ever emitted — merges unused"
    );
}

#[test]
fn training_is_deterministic_per_text() {
    let text = corpus(1);
    let slice = char_prefix(&text, 25_000);
    let a = ByteTokenizer::train(slice, VOCAB).unwrap();
    let b = ByteTokenizer::train(slice, VOCAB).unwrap();
    assert_eq!(a.n_merges(), b.n_merges());
    assert_eq!(a.encode(&text), b.encode(&text));
}

#[test]
fn merges_roundtrip_across_corpus_seeds() {
    // tokenizers trained on differently-seeded corpora learn different
    // merges, but every one of them must round-trip arbitrary text exactly
    // (vocab 320 keeps the 3× training affordable in debug builds; the 512
    // path is covered above)
    let probe = corpus(99);
    for seed in [2, 3, 4] {
        let text = corpus(seed);
        let tok = ByteTokenizer::train(char_prefix(&text, 20_000), 320).unwrap();
        assert!(tok.n_merges() > 0, "seed {seed}");
        let ids = tok.encode(&probe);
        assert_eq!(tok.decode(&ids).unwrap(), probe, "seed {seed}");
        assert!(ids.iter().all(|&i| (i as usize) < 320), "seed {seed}");
    }
}
