// Too slow under the Miri interpreter (and process-spawning tests cannot
// run there at all) -- the Miri lane drives tests/miri_parity.rs instead.
#![cfg(not(miri))]
#![forbid(unsafe_code)]
//! Exhaustive interleaving model of `native::pool`'s job protocol, checked
//! with the dependency-free explorer in `util::modelcheck` on every
//! `cargo test` run.
//!
//! The real pool (see `native/pool.rs`) distributes a batch of `n` tasks by:
//!
//! 1. every draining thread (workers *and* the submitter) claiming indices
//!    with an atomic `next.fetch_add(1)` until the counter passes `n`;
//! 2. running the claimed task, recording the *first* panic payload in a
//!    shared slot;
//! 3. decrementing a `pending` countdown **after** the task body finishes;
//! 4. the submitter waiting for `pending == 0` before taking the panic slot
//!    and returning.
//!
//! Each of those is one atomic step here, and `explore` walks every
//! interleaving of two workers plus the submitter over three tasks (two of
//! which "panic"). The invariants encode exactly the guarantees the pool's
//! ordering comments claim:
//!
//! - no task runs twice (the `fetch_add` claim is unique);
//! - `pending` never goes negative;
//! - **once the submitter has observed `pending == 0`, every task has
//!   executed** — the Acquire-load/AcqRel-countdown contract;
//! - the terminal state delivers exactly one of the recorded panics.
//!
//! Two deliberately broken variants — decrementing `pending` *before*
//! running the task, and splitting the claim into a non-atomic read +
//! increment — must be caught, proving the checker has teeth. Weak-memory
//! reorderings are out of scope here; they belong to `tests/loom_pool.rs`
//! (`--features loom`) and the TSan CI lane.

use repro::util::modelcheck::{explore, ThreadSpec};

const NTASKS: usize = 3;
/// Tasks 1 and 2 panic; the slot must keep whichever got there first.
const PANICKY: [bool; NTASKS] = [false, true, true];
/// Thread ids: 0, 1 = workers; 2 = submitter.
const SUBMITTER: usize = 2;

// Program-counter values (per draining thread):
const PC_CLAIM: u8 = 0; //   atomically claim an index (read + increment)
const PC_EXEC: u8 = 1; //    run the claimed task
const PC_DEC: u8 = 2; //     decrement `pending`
const PC_WAIT: u8 = 3; //    submitter only: wait for `pending == 0`
const PC_DONE: u8 = 4; //    terminated
// Broken-claim variant only:
const PC_INC: u8 = 5; //     second half of a torn (non-atomic) claim

#[derive(Clone, PartialEq, Eq, Hash)]
struct Pool {
    /// How many times each task body ran.
    executed: [u8; NTASKS],
    /// The shared claim counter.
    next: u8,
    /// The completion countdown (signed so underflow is observable).
    pending: i8,
    /// First panic payload recorded (task index), if any.
    panic_slot: Option<u8>,
    /// Payload the submitter took after the wait.
    delivered: Option<u8>,
    pc: [u8; 3],
    /// Claimed task index, per thread.
    reg: [u8; 3],
}

fn init() -> Pool {
    Pool {
        executed: [0; NTASKS],
        next: 0,
        pending: NTASKS as i8,
        panic_slot: None,
        delivered: None,
        pc: [PC_CLAIM, PC_CLAIM, PC_CLAIM],
        reg: [0; 3],
    }
}

fn done(s: &Pool, tid: usize) -> bool {
    s.pc[tid] == PC_DONE
}

/// The submitter's `pending` wait is the only blocking point: it is modeled
/// as "not runnable until the predicate holds", exactly like the real
/// Acquire spin / condvar wait.
fn runnable(s: &Pool, tid: usize) -> bool {
    s.pc[tid] != PC_WAIT || s.pending == 0
}

/// Steps shared by all variants: execute, decrement, wait.
/// Returns true if it handled the pc.
fn common_step(s: &mut Pool, tid: usize) -> bool {
    match s.pc[tid] {
        PC_EXEC => {
            let i = s.reg[tid] as usize;
            s.executed[i] += 1;
            if PANICKY[i] && s.panic_slot.is_none() {
                s.panic_slot = Some(i as u8);
            }
            s.pc[tid] = PC_DEC;
            true
        }
        PC_DEC => {
            s.pending -= 1;
            s.pc[tid] = PC_CLAIM;
            true
        }
        PC_WAIT => {
            // Only reachable when `pending == 0` (see `runnable`): take the
            // panic payload and return, as `Pool::run` does.
            s.delivered = s.panic_slot.take();
            s.pc[tid] = PC_DONE;
            true
        }
        _ => false,
    }
}

fn after_claims_exhausted(s: &mut Pool, tid: usize) {
    // Workers go back to sleep on the job condvar (done for this batch);
    // the submitter falls through to the completion wait.
    s.pc[tid] = if tid == SUBMITTER { PC_WAIT } else { PC_DONE };
}

/// Faithful model: the claim is one indivisible read-modify-write
/// (`next.fetch_add(1, Relaxed)`).
fn correct_step(s: &mut Pool, tid: usize) {
    if common_step(s, tid) {
        return;
    }
    debug_assert_eq!(s.pc[tid], PC_CLAIM);
    let i = s.next;
    s.next += 1;
    if (i as usize) < NTASKS {
        s.reg[tid] = i;
        s.pc[tid] = PC_EXEC;
    } else {
        after_claims_exhausted(s, tid);
    }
}

/// Seeded bug #1: the countdown is decremented BEFORE the task body runs.
/// The submitter can then observe `pending == 0` while a claimed task has
/// not executed yet — the exact bug the AcqRel-after-work ordering exists
/// to prevent.
fn early_countdown_step(s: &mut Pool, tid: usize) {
    match s.pc[tid] {
        PC_CLAIM => {
            let i = s.next;
            s.next += 1;
            if (i as usize) < NTASKS {
                s.reg[tid] = i;
                s.pc[tid] = PC_DEC;
            } else {
                after_claims_exhausted(s, tid);
            }
        }
        PC_DEC => {
            s.pending -= 1;
            s.pc[tid] = PC_EXEC;
        }
        PC_EXEC => {
            let i = s.reg[tid] as usize;
            s.executed[i] += 1;
            if PANICKY[i] && s.panic_slot.is_none() {
                s.panic_slot = Some(i as u8);
            }
            s.pc[tid] = PC_CLAIM;
        }
        _ => {
            let handled = common_step(s, tid);
            debug_assert!(handled);
        }
    }
}

/// Seeded bug #2: the claim is torn into a plain read followed by a plain
/// increment (what `next` being a non-atomic would allow). Two threads can
/// read the same index and run the same task twice.
fn torn_claim_step(s: &mut Pool, tid: usize) {
    match s.pc[tid] {
        PC_CLAIM => {
            s.reg[tid] = s.next;
            s.pc[tid] = PC_INC;
        }
        PC_INC => {
            s.next = s.reg[tid] + 1;
            if (s.reg[tid] as usize) < NTASKS {
                s.pc[tid] = PC_EXEC;
            } else {
                after_claims_exhausted(s, tid);
            }
        }
        _ => {
            let handled = common_step(s, tid);
            debug_assert!(handled);
        }
    }
}

fn threads(step: fn(&mut Pool, usize)) -> Vec<ThreadSpec<Pool>> {
    vec![
        ThreadSpec { name: "worker-0", done, runnable, step },
        ThreadSpec { name: "worker-1", done, runnable, step },
        ThreadSpec { name: "submitter", done, runnable, step },
    ]
}

fn invariant(s: &Pool) -> Result<(), String> {
    for (i, &n) in s.executed.iter().enumerate() {
        if n > 1 {
            return Err(format!("task {i} executed {n} times"));
        }
    }
    if s.pending < 0 {
        return Err(format!("pending underflowed to {}", s.pending));
    }
    // The load-bearing contract: once the submitter is past its completion
    // wait, every task body must have run to completion.
    if s.pc[SUBMITTER] == PC_DONE {
        for (i, &n) in s.executed.iter().enumerate() {
            if n != 1 {
                return Err(format!(
                    "submitter returned but task {i} executed {n} times (early completion)"
                ));
            }
        }
    }
    Ok(())
}

fn terminal(s: &Pool) -> Result<(), String> {
    if s.executed != [1; NTASKS] {
        return Err(format!("executed counts {:?}, want all 1", s.executed));
    }
    if s.pending != 0 {
        return Err(format!("pending ended at {}", s.pending));
    }
    match s.delivered {
        Some(i) if PANICKY[i as usize] => {}
        other => return Err(format!("delivered panic payload {other:?}, want a panicky task")),
    }
    if s.panic_slot.is_some() {
        return Err("panic slot not drained by the submitter".to_string());
    }
    Ok(())
}

const MAX_STATES: usize = 200_000;

#[test]
fn pool_protocol_has_no_bad_interleaving() {
    let cov = explore(init(), &threads(correct_step), invariant, terminal, MAX_STATES)
        .expect("the claim/countdown/panic protocol must hold under every interleaving");
    // Sanity: the exploration actually did work — three threads over three
    // tasks have well over a hundred distinct states.
    assert!(cov.states > 100, "suspiciously small state space: {:?}", cov);
    assert!(cov.terminals >= 1, "no terminal state reached: {:?}", cov);
}

#[test]
fn checker_catches_countdown_before_execution() {
    let err = explore(init(), &threads(early_countdown_step), invariant, terminal, MAX_STATES)
        .expect_err("decrementing pending before the task body must be caught");
    assert!(
        err.contains("early completion"),
        "expected the early-completion invariant to trip, got: {err}"
    );
}

#[test]
fn checker_catches_a_torn_claim() {
    let err = explore(init(), &threads(torn_claim_step), invariant, terminal, MAX_STATES)
        .expect_err("a non-atomic claim counter must be caught");
    assert!(
        err.contains("executed") || err.contains("underflowed"),
        "expected a double-execution or underflow, got: {err}"
    );
}
