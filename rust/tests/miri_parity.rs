//! Size-reduced parity suite for the Miri and ThreadSanitizer CI lanes.
//!
//! Miri interprets MIR ~2 orders of magnitude slower than native code, so
//! the heavyweight integration tests are `#![cfg(not(miri))]`-gated and this
//! file is the sanctioned entry point:
//!
//! ```text
//! cargo +nightly miri test --test miri_parity
//! ```
//!
//! Every family of `unsafe` in the crate is driven here through real
//! multi-thread pool submissions, at shapes shrunk under `cfg!(miri)`:
//!
//! - the kernel families (scan / chunkwise / quadratic / softmax) — their
//!   parallel paths write through `SliceParts` raw-pointer windows;
//! - the in-place AdamW update — `StateViews` aliased parameter pointers;
//! - the decode hot path — `DecodeScratch` reuse plus its windowed stores.
//!
//! The checks are *parity* checks (independent implementations agreeing),
//! not just smoke: if a window overlaps or a store is torn, the numbers
//! disagree even when the UB happens not to crash.

use repro::infer::DecodeState;
use repro::native::kernels::{
    la_chunk_bwd, la_chunk_fwd, la_quadratic_bwd, la_quadratic_fwd, la_scan_bwd, la_scan_fwd,
    softmax_bwd, softmax_fwd, LayerShape,
};
use repro::native::model::{self, AttnKind, DecodeScratch, LmConfig, Precision, PrefillScratch};
use repro::native::pool::ThreadPool;
use repro::runtime::Tensor;

/// Layer shape: tiny under Miri, small-but-parallel otherwise (both spill
/// across several pool tasks so the windowed writes genuinely interleave).
fn shape() -> (LayerShape, usize) {
    if cfg!(miri) {
        (LayerShape::cube(2, 8, 4), 4) // (shape, chunk)
    } else {
        (LayerShape::cube(2, 32, 8), 8)
    }
}

/// LM config: the `tiny` preset natively, shrunk far below it under Miri.
fn lm_cfg(attn: AttnKind) -> LmConfig {
    let mut cfg = LmConfig::tiny(attn);
    if cfg!(miri) {
        cfg.vocab = 31;
        cfg.n_ctx = 8;
        cfg.d_model = 8;
        cfg.n_layer = 1;
        cfg.n_head = 2;
        cfg.d_ff = 16;
        cfg.batch = 2;
    }
    cfg
}

fn flat_randn(n: usize, seed: u64) -> Vec<f32> {
    match Tensor::randn(vec![n], seed) {
        Tensor::F32 { data, .. } => data,
        _ => unreachable!(),
    }
}

fn layer_inputs(sh: LayerShape, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut q = Tensor::randn(vec![sh.bh, sh.n, sh.dk], seed);
    let mut k = Tensor::randn(vec![sh.bh, sh.n, sh.dk], seed + 1);
    q.normalize_rows();
    k.normalize_rows();
    let v = flat_randn(sh.bh * sh.n * sh.dv, seed + 2);
    let go = flat_randn(sh.bh * sh.n * sh.dv, seed + 3);
    let (Tensor::F32 { data: q, .. }, Tensor::F32 { data: k, .. }) = (q, k) else {
        unreachable!()
    };
    (q, k, v, go)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

const TOL: f32 = 1e-4;

#[test]
fn linear_kernel_families_agree_under_the_interpreter() {
    let (sh, chunk) = shape();
    let pool = ThreadPool::new(2);
    let (q, k, v, go) = layer_inputs(sh, 0xC1);

    let reference = la_quadratic_fwd(&pool, &q, &k, &v, sh);
    let scan = la_scan_fwd(&pool, &q, &k, &v, sh, 1.0);
    let chunked = la_chunk_fwd(&pool, &q, &k, &v, sh, chunk);
    assert!(max_abs_diff(&scan, &reference) < TOL, "scan fwd diverged");
    assert!(max_abs_diff(&chunked, &reference) < TOL, "chunk fwd diverged");

    let (rq, rk, rv) = la_quadratic_bwd(&pool, &q, &k, &v, &go, sh);
    let (sq, sk, sv) = la_scan_bwd(&pool, &q, &k, &v, &go, sh, 1.0);
    let (cq, ck, cv) = la_chunk_bwd(&pool, &q, &k, &v, &go, sh, chunk);
    for (name, got, want) in [
        ("scan dq", &sq, &rq),
        ("scan dk", &sk, &rk),
        ("scan dv", &sv, &rv),
        ("chunk dq", &cq, &rq),
        ("chunk dk", &ck, &rk),
        ("chunk dv", &cv, &rv),
    ] {
        assert!(max_abs_diff(got, want) < TOL, "{name} diverged");
    }
}

#[test]
fn softmax_kernel_is_causal_and_finite_under_the_interpreter() {
    let (sh, _) = shape();
    let pool = ThreadPool::new(2);
    let (q, k, v, go) = layer_inputs(sh, 0xC7);
    let scale = 1.0 / (sh.dk as f32).sqrt();

    let o = softmax_fwd(&pool, &q, &k, &v, sh, scale);
    assert_eq!(o.len(), sh.bh * sh.n * sh.dv);
    assert!(o.iter().all(|x| x.is_finite()));
    // causality: row 0 attends only to itself, so it IS v's row 0
    for b in 0..sh.bh {
        let got = &o[b * sh.n * sh.dv..][..sh.dv];
        let want = &v[b * sh.n * sh.dv..][..sh.dv];
        assert!(max_abs_diff(got, want) < TOL, "softmax row 0 of bh {b} is not v[0]");
    }

    let (dq, dk, dv) = softmax_bwd(&pool, &q, &k, &v, &go, sh, scale);
    assert_eq!(dq.len(), q.len());
    assert_eq!(dk.len(), k.len());
    assert_eq!(dv.len(), v.len());
    assert!(dq.iter().chain(&dk).chain(&dv).all(|x| x.is_finite()));
    // causality in the backward: dv's LAST row gets gradient only from the
    // last query row, with weight softmax(last)·go(last) — finite + nonzero
    let last = &dv[(sh.bh * sh.n - 1) * sh.dv..];
    assert!(last.iter().any(|x| *x != 0.0), "dv last row unexpectedly all-zero");
}

#[test]
fn in_place_adamw_matches_itself_across_scratch_reuse() {
    let cfg = lm_cfg(AttnKind::Ours);
    let g: Vec<Vec<f32>> = cfg
        .param_shapes()
        .iter()
        .map(|(_, s)| {
            (0..s.iter().product::<usize>()).map(|j| ((j % 7) as f32 - 3.0) * 1e-3).collect()
        })
        .collect();
    let pool = ThreadPool::new(2);

    // route A: fresh scratch every step (the convenience wrapper)
    let mut sa = cfg.init_state(11);
    let mut norms_a = Vec::new();
    for step in 0..3 {
        norms_a.push(model::adamw_update_mut(&cfg, &mut sa, &g, step, &pool).unwrap());
    }
    // route B: one warm scratch across steps (the training-loop path)
    let mut sb = cfg.init_state(11);
    let mut sc = model::AdamwScratch::new();
    for (step, na) in norms_a.iter().enumerate() {
        let nb = model::adamw_update_mut_scratch(&cfg, &mut sb, &g, step, &pool, &mut sc).unwrap();
        assert_eq!(*na, nb, "grad norm diverged at step {step}");
    }
    for (a, b) in sa.iter().zip(sb.iter()) {
        let (Tensor::F32 { data: da, .. }, Tensor::F32 { data: db, .. }) = (a, b) else {
            panic!("non-f32 state array")
        };
        assert_eq!(da, db, "scratch reuse changed the update");
    }
}

#[test]
fn decode_scratch_reuse_matches_the_fresh_scratch_path() {
    for attn in [AttnKind::Ours, AttnKind::Softmax] {
        let cfg = lm_cfg(attn);
        let mut state = cfg.init_state(5);
        state.truncate(cfg.n_param_arrays());
        let params: Vec<&Tensor> = state.iter().collect();
        let pool = ThreadPool::new(2);
        let bound = model::DecodeModel::bind(&cfg, &params).unwrap();

        let mut st_a = DecodeState::new(&cfg, 2).unwrap();
        let mut st_b = DecodeState::new(&cfg, 2).unwrap();
        let mut sc = DecodeScratch::new();
        let steps = if cfg!(miri) { 3 } else { 8 };
        for t in 0..steps {
            let toks = [(t % cfg.vocab) as i32, ((t + 2) % cfg.vocab) as i32];
            let fresh = bound.logits_step(&toks, &mut st_a, &pool).unwrap();
            let reused = bound.logits_step_scratch(&toks, &mut st_b, &pool, &mut sc).unwrap();
            assert_eq!(fresh.as_slice(), reused, "token {t} ({attn:?}): scratch reuse diverged");
        }
    }
}

/// Size-reduced twin of the chunked-prefill parity suite in
/// `tests/infer.rs`: the chunked route drives the carry kernel's
/// `SliceParts` state windows and the blocked-softmax score windows through
/// real pool submissions, so Miri/TSan must see it too. Serial
/// (`prefill_step`) and chunked (`prefill_chunked_with`) prompt ingestion
/// must land in states that produce the same next-token logits.
#[test]
fn chunked_prefill_matches_serial_under_the_interpreter() {
    // looser than the kernel TOL: layer-stacked GEMM reassociation
    let tol = 2e-3f32;
    for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
        let cfg = lm_cfg(attn);
        let mut state = cfg.init_state(7);
        state.truncate(cfg.n_param_arrays());
        let params: Vec<&Tensor> = state.iter().collect();
        let pool = ThreadPool::new(2);
        let bound = model::DecodeModel::bind(&cfg, &params).unwrap();
        // several chunks plus a ragged tail at either scale
        let (l, chunk) = if cfg!(miri) { (5, 2) } else { (40, 16) };
        let toks: Vec<i32> = (0..l).map(|i| ((i * 31 + 7) % cfg.vocab) as i32).collect();

        let mut st_s = DecodeState::new(&cfg, 1).unwrap();
        let mut dsc = DecodeScratch::new();
        for &t in &toks {
            bound.prefill_step_scratch(&[t], &mut st_s, &pool, &mut dsc).unwrap();
        }
        let a = bound.logits_step(&[3], &mut st_s, &pool).unwrap();

        let mut st_c = DecodeState::new(&cfg, 1).unwrap();
        let mut psc = PrefillScratch::new();
        bound.prefill_chunked_with(chunk, &toks, &mut st_c, &pool, &mut psc).unwrap();
        assert_eq!(st_s.pos(), st_c.pos(), "{attn:?}: position skew");
        let b = bound.logits_step(&[3], &mut st_c, &pool).unwrap();

        assert!(b.iter().all(|x| x.is_finite()), "{attn:?}");
        let d = max_abs_diff(&a, &b);
        assert!(d < tol, "{attn:?}: chunked prefill diverged from serial (max {d})");
        #[cfg(not(feature = "simd"))]
        if attn == AttnKind::Softmax {
            assert_eq!(a, b, "softmax prefill must be bit-exact off-simd");
        }
    }
}

/// The quantized decode path drives fresh `unsafe` families (bf16/int8 GEMM
/// microkernel tails, the dequantize → f32 scan → requantize state windows)
/// through real pool submissions, so it gets its own size-reduced parity
/// case. Three claims:
/// - an f32-precision [`model::QuantModel`] is **bit-exact** vs direct
///   parameter binding (the storage indirection is free);
/// - bf16/int8 logits track the f32 oracle within a loose rounding bound —
///   a torn window or overlapping store produces garbage far outside it;
/// - quantized fresh-state vs scratch-reuse decode agree **exactly**
///   (requantization is deterministic).
#[test]
fn quantized_decode_tracks_the_f32_oracle_under_the_interpreter() {
    for attn in [AttnKind::Ours, AttnKind::Softmax] {
        let cfg = lm_cfg(attn);
        let mut state = cfg.init_state(9);
        state.truncate(cfg.n_param_arrays());
        let params: Vec<&Tensor> = state.iter().collect();
        let pool = ThreadPool::new(2);
        let oracle = model::DecodeModel::bind(&cfg, &params).unwrap();

        for (prec, tol) in [(Precision::F32, 0.0f32), (Precision::Bf16, 0.75), (Precision::Int8, 0.75)]
        {
            let qm = model::QuantModel::from_params(&cfg, &params, prec).unwrap();
            let bound = model::DecodeModel::bind_quantized(&qm).unwrap();
            let mut st_o = DecodeState::new(&cfg, 2).unwrap();
            let mut st_a = DecodeState::new(qm.cfg(), 2).unwrap();
            let mut st_b = DecodeState::new(qm.cfg(), 2).unwrap();
            let mut sc = DecodeScratch::new();
            let steps = if cfg!(miri) { 3 } else { 8 };
            for t in 0..steps {
                let toks = [(t % cfg.vocab) as i32, ((t + 2) % cfg.vocab) as i32];
                let want = oracle.logits_step(&toks, &mut st_o, &pool).unwrap();
                let fresh = bound.logits_step(&toks, &mut st_a, &pool).unwrap();
                let reused = bound.logits_step_scratch(&toks, &mut st_b, &pool, &mut sc).unwrap();
                assert_eq!(
                    fresh.as_slice(),
                    reused,
                    "token {t} ({attn:?}, {prec}): quantized scratch reuse diverged"
                );
                assert!(fresh.iter().all(|x| x.is_finite()), "token {t} ({attn:?}, {prec})");
                let d = max_abs_diff(&fresh, &want);
                if prec == Precision::F32 {
                    assert_eq!(
                        fresh, want,
                        "token {t} ({attn:?}): f32 QuantModel storage is not bit-exact"
                    );
                } else {
                    assert!(d < tol, "token {t} ({attn:?}, {prec}): drift {d} vs f32 oracle");
                }
            }
        }
    }
}
