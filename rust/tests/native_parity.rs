//! Numerical parity of the native `ours` kernels (scan + chunkwise) against
//! the quadratic softmax-free reference, and an end-to-end CLI smoke test of
//! `repro train` on the tiny preset.

// Too slow under the Miri interpreter (and process-spawning tests cannot
// run there at all) -- the Miri lane drives tests/miri_parity.rs instead.
#![cfg(not(miri))]

use repro::native::kernels::{
    la_chunk_bwd, la_chunk_fwd, la_quadratic_bwd, la_quadratic_fwd, la_scan_bwd, la_scan_fwd,
    LayerShape,
};
use repro::native::pool::ThreadPool;
use repro::runtime::Tensor;

fn flat_randn(n: usize, seed: u64) -> Vec<f32> {
    match Tensor::randn(vec![n], seed) {
        Tensor::F32 { data, .. } => data,
        _ => unreachable!(),
    }
}

/// q/k drawn as unit rows (paper §3.3 normalization), v/go plain normal.
fn layer_inputs(sh: LayerShape, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut q = Tensor::randn(vec![sh.bh, sh.n, sh.dk], seed);
    let mut k = Tensor::randn(vec![sh.bh, sh.n, sh.dk], seed + 1);
    q.normalize_rows();
    k.normalize_rows();
    let v = flat_randn(sh.bh * sh.n * sh.dv, seed + 2);
    let go = flat_randn(sh.bh * sh.n * sh.dv, seed + 3);
    let q = match q {
        Tensor::F32 { data, .. } => data,
        _ => unreachable!(),
    };
    let k = match k {
        Tensor::F32 { data, .. } => data,
        _ => unreachable!(),
    };
    (q, k, v, go)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

const PARITY_SHAPES: [(usize, usize); 2] = [(64, 16), (256, 32)];
const TOL: f32 = 1e-4;

#[test]
fn ours_forward_matches_quadratic_reference() {
    let pool = ThreadPool::global();
    for (n, d) in PARITY_SHAPES {
        let sh = LayerShape::cube(2, n, d);
        let (q, k, v, _go) = layer_inputs(sh, 0xA0 + n as u64);
        let reference = la_quadratic_fwd(pool, &q, &k, &v, sh);
        let scan = la_scan_fwd(pool, &q, &k, &v, sh, 1.0);
        let chunk = la_chunk_fwd(pool, &q, &k, &v, sh, 64);
        assert!(
            max_abs_diff(&scan, &reference) < TOL,
            "scan fwd (N={n}, D={d}): {}",
            max_abs_diff(&scan, &reference)
        );
        assert!(
            max_abs_diff(&chunk, &reference) < TOL,
            "chunk fwd (N={n}, D={d}): {}",
            max_abs_diff(&chunk, &reference)
        );
    }
}

#[test]
fn ours_backward_matches_quadratic_reference() {
    let pool = ThreadPool::global();
    for (n, d) in PARITY_SHAPES {
        let sh = LayerShape::cube(2, n, d);
        let (q, k, v, go) = layer_inputs(sh, 0xB0 + n as u64);
        let (rq, rk, rv) = la_quadratic_bwd(pool, &q, &k, &v, &go, sh);
        let (sq, sk, sv) = la_scan_bwd(pool, &q, &k, &v, &go, sh, 1.0);
        let (cq, ck, cv) = la_chunk_bwd(pool, &q, &k, &v, &go, sh, 64);
        for (name, got, want) in [
            ("scan dq", &sq, &rq),
            ("scan dk", &sk, &rk),
            ("scan dv", &sv, &rv),
            ("chunk dq", &cq, &rq),
            ("chunk dk", &ck, &rk),
            ("chunk dv", &cv, &rv),
        ] {
            assert!(
                max_abs_diff(got, want) < TOL,
                "{name} (N={n}, D={d}): {}",
                max_abs_diff(got, want)
            );
        }
    }
}

#[test]
fn repro_train_cli_smoke_loss_is_finite_and_decreasing() {
    let out_dir = std::env::temp_dir().join("repro_cli_smoke");
    let _ = std::fs::remove_dir_all(&out_dir);
    std::fs::create_dir_all(&out_dir).unwrap();

    let status = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args([
            "train",
            "--preset",
            "tiny",
            "--attn",
            "ours",
            "--steps",
            "5",
            "--eval-every",
            "0",
            "--out",
        ])
        .arg(&out_dir)
        .status()
        .expect("repro binary must launch");
    assert!(status.success(), "repro train exited with {status}");

    let metrics = out_dir.join("lm_tiny_ours").join("metrics.jsonl");
    let log = repro::coordinator::MetricsLog::read_jsonl(&metrics).unwrap();
    let recs = log.records();
    assert_eq!(recs.len(), 5);
    for r in recs {
        assert!(r.loss.is_finite(), "step {} loss {}", r.step, r.loss);
    }
    assert!(
        recs.last().unwrap().loss < recs[0].loss,
        "loss did not decrease: {} → {}",
        recs[0].loss,
        recs.last().unwrap().loss
    );
}
