//! Loom model-checking of the thread-pool job-completion protocol.
//!
//! Compiled only with `--features loom`, which (a) swaps every
//! synchronization primitive inside `native::pool` to `loom::sync` via its
//! `sync` shim, and (b) requires the commented-out `loom` dev-dependency in
//! `rust/Cargo.toml` to be enabled:
//!
//! ```text
//! sed -i 's|^# loom = |loom = |' rust/Cargo.toml
//! LOOM_MAX_PREEMPTIONS=2 cargo test --release --features loom --test loom_pool
//! ```
//!
//! What loom adds over `tests/pool_model.rs` (the always-on SC model): weak
//! memory. Loom explores the C11 orderings the pool actually writes —
//! these tests fail if `pending`'s `AcqRel` decrement chain or the
//! submitter's `Acquire` completion load is weakened to `Relaxed`, because
//! the non-atomic task writes below go through `loom::cell::UnsafeCell`,
//! which reports any access not ordered by a happens-before edge.
//!
//! Panic *propagation* is deliberately not modeled here: a real unwind
//! inside a loom model aborts the exploration, so those paths are covered
//! by the `std`-build tests in `native/pool.rs` and the SC model instead.

#![cfg(feature = "loom")]

use loom::cell::UnsafeCell;
use repro::native::pool::ThreadPool;

/// Shared output buffer written non-atomically by pool tasks, exactly like
/// the kernels' `SliceParts` windows — loom tracks every access and fails
/// the model if two threads touch a cell without a happens-before edge.
struct Cells {
    slots: Vec<UnsafeCell<usize>>,
}

// SAFETY: each pool task writes only its own index (disjoint cells), and the
// submitter reads only after `run` returns; the pool's completion protocol
// must order those accesses — proving that is the entire point of the model.
unsafe impl Sync for Cells {}

impl Cells {
    fn new(n: usize) -> Self {
        Self { slots: (0..n).map(|_| UnsafeCell::new(0)).collect() }
    }

    fn put(&self, i: usize, v: usize) {
        // SAFETY: task `i` is the only writer of slot `i` while the job runs.
        self.slots[i].with_mut(|p| unsafe { *p = v });
    }

    fn get(&self, i: usize) -> usize {
        // SAFETY: called by the submitter after `run` returned; the
        // completion Acquire must make this race-free (loom checks).
        self.slots[i].with(|p| unsafe { *p })
    }
}

/// Two tasks drained by a worker and the submitter together: every
/// interleaving must complete both tasks exactly once, and the task writes
/// must be visible to the submitter without extra synchronization.
#[test]
fn run_completes_all_tasks_and_publishes_their_writes() {
    loom::model(|| {
        let pool = ThreadPool::new(2);
        let out = Cells::new(2);
        pool.run(2, |i| out.put(i, i + 10));
        for i in 0..2 {
            assert_eq!(out.get(i), i + 10, "task {i} write lost");
        }
        drop(pool); // worker shutdown handshake is part of the model
    });
}

/// Two back-to-back submissions on one pool: the epoch bump must hand the
/// second job to a worker that may still be waking from the first, and the
/// second job's writes must overwrite the first's.
#[test]
fn pool_reuse_keeps_jobs_separate() {
    loom::model(|| {
        let pool = ThreadPool::new(2);
        let out = Cells::new(2);
        pool.run(2, |i| out.put(i, 1));
        pool.run(2, |i| out.put(i, out.get(i) + 1));
        for i in 0..2 {
            assert_eq!(out.get(i), 2, "slot {i} saw a stale job");
        }
    });
}

/// A task that re-enters the pool must run the nested job inline on the
/// calling thread (the pool runs one job at a time — re-submitting would
/// deadlock). The nested writes land in disjoint cells of the same buffer.
#[test]
fn nested_submission_runs_inline_without_deadlock() {
    loom::model(|| {
        let pool = ThreadPool::new(2);
        let out = Cells::new(4);
        let p2 = pool.clone();
        pool.run(2, |i| {
            // nested run: IN_POOL_TASK is set, so this must stay inline
            p2.run(2, |j| out.put(i * 2 + j, 7));
        });
        for i in 0..4 {
            assert_eq!(out.get(i), 7, "nested task {i} missing");
        }
    });
}

/// Degenerate shapes run fully inline — no worker interaction at all, so
/// the model is trivial, but it pins the inline fast paths under the same
/// instrumented build.
#[test]
fn single_thread_and_single_task_shapes_run_inline() {
    loom::model(|| {
        let out = Cells::new(3);
        ThreadPool::new(1).run(3, |i| out.put(i, i));
        for i in 0..3 {
            assert_eq!(out.get(i), i);
        }
        let pool = ThreadPool::new(2);
        pool.run(1, |i| out.put(i, 99)); // single task: inline, no publish
        assert_eq!(out.get(0), 99);
    });
}
