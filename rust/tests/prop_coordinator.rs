//! Property-based tests over coordinator invariants.
//!
//! proptest is unavailable offline, so this file uses a small in-file
//! randomized-property harness driven by the repo's own SplitMix64: each
//! property runs across hundreds of random cases with a deterministic seed,
//! and failures report the case index for replay.

// Too slow under the Miri interpreter (and process-spawning tests cannot
// run there at all) -- the Miri lane drives tests/miri_parity.rs instead.
#![cfg(not(miri))]

use repro::bench::TimingStats;
use repro::coordinator::schedule::CosineSchedule;
use repro::coordinator::checkpoint::{Checkpoint, CheckpointMeta};
use repro::data::rng::SplitMix64;
use repro::data::{ByteTokenizer, PackedDataset, Split};
use repro::runtime::Tensor;
use repro::simulator::{DeviceSpec, Impl, TrafficModel};
use repro::util::json::Json;

/// Run `prop` for `cases` seeded cases; panic with the failing case index.
fn forall(cases: u64, name: &str, mut prop: impl FnMut(&mut SplitMix64)) {
    for case in 0..cases {
        let mut rng = SplitMix64::new(0xBADC0DE ^ case.wrapping_mul(0x9E3779B9));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            panic!("property {name} failed at case {case}: {e:?}");
        }
    }
}

fn random_ascii(rng: &mut SplitMix64, max_len: usize) -> String {
    let len = rng.below(max_len + 1);
    (0..len)
        .map(|_| (32 + rng.below(95)) as u8 as char)
        .collect()
}

#[test]
fn prop_tokenizer_roundtrips_any_ascii() {
    forall(200, "tokenizer_roundtrip", |rng| {
        let train_text = random_ascii(rng, 400);
        let vocab = 256 + rng.below(64);
        let tok = ByteTokenizer::train(&train_text, vocab).unwrap();
        let probe = random_ascii(rng, 200);
        let ids = tok.encode(&probe);
        assert_eq!(tok.decode(&ids).unwrap(), probe);
        assert!(ids.iter().all(|&i| (i as usize) < vocab));
    });
}

#[test]
fn prop_dataset_split_partitions_rows() {
    forall(100, "dataset_partition", |rng| {
        let n_tokens = 200 + rng.below(4000);
        let seq = 4 + rng.below(12);
        let tokens: Vec<i32> = (0..n_tokens as i32).collect();
        let Ok(ds) = PackedDataset::pack(&tokens, seq, 0.2, rng.next_u64()) else {
            return; // too small is allowed to error
        };
        let row_len = seq + 1;
        let expected_rows = n_tokens / row_len;
        assert_eq!(ds.len(Split::Train) + ds.len(Split::Val), expected_rows);
        // every row is a contiguous slice of the source stream
        for row in ds.rows(Split::Train).iter().chain(ds.rows(Split::Val)) {
            let start = row[0];
            for (i, &t) in row.iter().enumerate() {
                assert_eq!(t, start + i as i32);
            }
        }
    });
}

#[test]
fn prop_schedule_bounded_and_continuous() {
    forall(100, "schedule_bounds", |rng| {
        let warm = 1 + rng.below(50);
        let total = warm + 1 + rng.below(500);
        let s = CosineSchedule::paper_defaults(warm, total);
        let mut prev = None;
        for step in 0..total + 50 {
            let lr = s.lr(step);
            assert!(lr >= -1e-15 && lr <= s.lr_max + 1e-15, "lr {lr} out of bounds");
            if let Some(p) = prev {
                let jump = (lr - p as f64).abs();
                // bounded by the warmup increment + the steepest cosine slope
                let span = (total - warm).max(1) as f64;
                let bound = s.lr_max / warm as f64
                    + std::f64::consts::PI * (s.lr_max - s.lr_min) / (2.0 * span);
                assert!(jump <= bound + 1e-9, "jump {jump} at {step}");
            }
            prev = Some(lr);
        }
        assert!((s.lr(total + 1000) - s.lr_min).abs() < 1e-12);
    });
}

#[test]
fn prop_checkpoint_roundtrips_random_states() {
    let dir = std::env::temp_dir().join("repro_prop_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    forall(30, "checkpoint_roundtrip", |rng| {
        let n_tensors = 1 + rng.below(6);
        let state: Vec<Tensor> = (0..n_tensors)
            .map(|i| {
                let rank = rng.below(3);
                let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(8)).collect();
                if i % 3 == 0 {
                    let n: usize = shape.iter().product();
                    Tensor::i32(shape, (0..n as i32).collect()).unwrap()
                } else {
                    Tensor::randn(shape, rng.next_u64())
                }
            })
            .collect();
        let ck = Checkpoint {
            meta: CheckpointMeta {
                artifact_tag: format!("t{}", rng.below(100)),
                step: rng.below(10_000),
                loss: rng.next_f64() as f32,
                seed: rng.next_u64(),
                layout: 1 + rng.below(3) as u32,
            },
            state,
        };
        let path = dir.join(format!("c{}.ckpt", rng.next_u64()));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.meta, ck.meta);
        assert_eq!(back.state, ck.state);
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn prop_json_roundtrips_random_values() {
    fn random_json(rng: &mut SplitMix64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.next_f64() * 1e6).round() / 8.0),
            3 => Json::Str(random_string(rng)),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    fn random_string(rng: &mut SplitMix64) -> String {
        let choices = ["plain", "with \"quotes\"", "line\nbreak", "tab\there", "uni ↯ é"];
        choices[rng.below(choices.len())].to_string()
    }
    forall(300, "json_roundtrip", |rng| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back, "text: {text}");
    });
}

#[test]
fn prop_timing_stats_ordering() {
    forall(200, "timing_ordering", |rng| {
        let n = 1 + rng.below(50);
        let samples: Vec<f64> = (0..n).map(|_| rng.next_f64() * 10.0 + 1e-6).collect();
        let s = TimingStats::from_samples(samples.clone()).unwrap();
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
        assert!(s.min <= s.mean && s.mean <= s.max);
        assert!(s.min <= s.trimmed_mean && s.trimmed_mean <= s.max);
        assert_eq!(s.reps, n);
    });
}

#[test]
fn prop_traffic_model_monotone_in_n_and_d() {
    let m = TrafficModel::new(DeviceSpec::a6000());
    forall(100, "traffic_monotone", |rng| {
        let imp = Impl::la_impls()[rng.below(4)];
        let bh = 1 + rng.below(64);
        let n = 512 * (1 + rng.below(16));
        let d = 32 * (1 + rng.below(8));
        let r = m.report(imp, bh, n, d);
        let r2n = m.report(imp, bh, n * 2, d);
        let r2d = m.report(imp, bh, n, d * 2);
        assert!(r2n.bytes > r.bytes);
        assert!(r2n.total_s > r.total_s);
        assert!(r2d.flops > r.flops);
        assert!(r.move_ratio() > 0.0 && r.move_ratio() < 1.0);
    });
}

#[test]
fn prop_ours_always_lowest_traffic_among_la() {
    let m = TrafficModel::new(DeviceSpec::a6000());
    forall(100, "ours_lowest_traffic", |rng| {
        let bh = 1 + rng.below(64);
        let n = 1024 * (1 + rng.below(32));
        let d = 32 * (1 + rng.below(8));
        let ours = m.report(Impl::Ours, bh, n, d);
        for imp in [Impl::Gated, Impl::Baseline, Impl::SpecDec] {
            assert!(
                m.report(imp, bh, n, d).bytes >= ours.bytes,
                "{imp:?} below ours at n={n} d={d}"
            );
        }
    });
}

#[test]
fn prop_batcher_covers_every_row_each_epoch() {
    forall(50, "batcher_coverage", |rng| {
        let tokens: Vec<i32> = (0..2_000).collect();
        let seq = 4 + rng.below(8);
        let ds = PackedDataset::pack(&tokens, seq, 0.1, rng.next_u64()).unwrap();
        let batch = 1 + rng.below(4);
        let mut b = repro::data::Batcher::new(&ds, Split::Train, batch, rng.next_u64()).unwrap();
        let per_epoch = b.batches_per_epoch();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..per_epoch {
            let t = b.next_batch().unwrap();
            for row in t.as_i32().unwrap().chunks(seq + 1) {
                seen.insert(row[0]);
            }
        }
        // full batches cover at least per_epoch * batch distinct rows
        assert!(seen.len() >= per_epoch * batch - batch + 1);
    });
}
