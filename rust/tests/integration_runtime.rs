//! Integration: the engine + native backend over the built-in artifact set.

// Too slow under the Miri interpreter (and process-spawning tests cannot
// run there at all) -- the Miri lane drives tests/miri_parity.rs instead.
#![cfg(not(miri))]

use repro::runtime::{Engine, Tensor};

fn engine() -> Engine {
    Engine::discover().expect("native backend must always construct")
}

#[test]
fn manifest_discovers_and_has_core_artifacts() {
    let e = engine();
    assert_eq!(e.platform(), "cpu");
    for name in ["quickstart_la_fwd", "quickstart_la_bwd", "quickstart_la_ref"] {
        assert!(e.manifest.get(name).is_ok(), "missing {name}");
    }
    assert!(!e.manifest.by_kind("layer_fwd").is_empty());
    assert!(!e.manifest.by_kind("lm_train_step").is_empty());
}

#[test]
fn kernel_forward_matches_oracle_artifact() {
    let e = engine();
    let fwd = e.load("quickstart_la_fwd").unwrap();
    let oracle = e.load("quickstart_la_ref").unwrap();
    let shape = fwd.meta.inputs[0].shape.clone();
    let mut q = Tensor::randn(shape.clone(), 11);
    let mut k = Tensor::randn(shape.clone(), 12);
    let v = Tensor::randn(shape.clone(), 13);
    q.normalize_rows();
    k.normalize_rows();
    let a = fwd.run(&[q.clone(), k.clone(), v.clone()]).unwrap();
    let b = oracle.run(&[q, k, v]).unwrap();
    let err = a[0]
        .as_f32()
        .unwrap()
        .iter()
        .zip(b[0].as_f32().unwrap())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 1e-4, "kernel vs oracle max err {err}");
}

#[test]
fn backward_artifact_produces_finite_grads() {
    let e = engine();
    let bwd = e.load("quickstart_la_bwd").unwrap();
    let shape = bwd.meta.inputs[0].shape.clone();
    let mut q = Tensor::randn(shape.clone(), 1);
    let mut k = Tensor::randn(shape.clone(), 2);
    q.normalize_rows();
    k.normalize_rows();
    let v = Tensor::randn(shape.clone(), 3);
    let go = Tensor::randn(shape.clone(), 4);
    let grads = bwd.run(&[q, k, v, go]).unwrap();
    assert_eq!(grads.len(), 3);
    for g in &grads {
        assert_eq!(g.shape(), shape.as_slice());
        assert!(g.as_f32().unwrap().iter().all(|x| x.is_finite()));
    }
}

#[test]
fn wrong_input_shape_is_rejected() {
    let e = engine();
    let fwd = e.load("quickstart_la_fwd").unwrap();
    let bad = Tensor::randn(vec![1, 2, 3], 0);
    let err = fwd.run(&[bad.clone(), bad.clone(), bad]);
    assert!(err.is_err());
}

#[test]
fn wrong_input_count_is_rejected() {
    let e = engine();
    let fwd = e.load("quickstart_la_fwd").unwrap();
    let shape = fwd.meta.inputs[0].shape.clone();
    let t = Tensor::randn(shape, 0);
    assert!(fwd.run(&[t]).is_err());
}

#[test]
fn unknown_artifact_is_a_clean_error() {
    let e = engine();
    let err = e.load("definitely_not_an_artifact").unwrap_err();
    assert!(err.to_string().contains("definitely_not_an_artifact"));
}

#[test]
fn executable_cache_returns_same_instance() {
    let e = engine();
    let a = e.load("quickstart_la_fwd").unwrap();
    let b = e.load("quickstart_la_fwd").unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
}

#[test]
fn scan_and_chunk_variants_agree_at_sweep_size() {
    let e = engine();
    let chunked = e.load("layer_ours_fwd_n1024_d128").unwrap();
    let scanned = e.load("layer_ours_scan_fwd_n1024_d128").unwrap();
    let shape = chunked.meta.inputs[0].shape.clone();
    let mut q = Tensor::randn(shape.clone(), 21);
    let mut k = Tensor::randn(shape.clone(), 22);
    q.normalize_rows();
    k.normalize_rows();
    let v = Tensor::randn(shape, 23);
    let a = chunked.run(&[q.clone(), k.clone(), v.clone()]).unwrap();
    let b = scanned.run(&[q, k, v]).unwrap();
    let err = a[0]
        .as_f32()
        .unwrap()
        .iter()
        .zip(b[0].as_f32().unwrap())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    // N=1024 accumulations in different orders: allow a few f32 ulps more
    // than the N=256 quickstart parity bound
    assert!(err < 5e-4, "chunk vs scan max err {err}");
}

#[test]
fn io_byte_accounting_matches_manifest() {
    let e = engine();
    let fwd = e.load("quickstart_la_fwd").unwrap();
    // 3 inputs of (4, 256, 64) f32
    assert_eq!(fwd.input_bytes(), 3 * 4 * 256 * 64 * 4);
    assert_eq!(fwd.output_bytes(), 4 * 256 * 64 * 4);
}
