//! Allocation-gate tests: the counting global allocator turns the repo's
//! "zero steady-state allocation" prose into assertions.
//!
//! Compiled only with `--features alloc-gate` (which installs
//! `util::alloc_gate::CountingAlloc` as `#[global_allocator]`):
//!
//! ```text
//! cargo test --features alloc-gate --test alloc_gate
//! ```
//!
//! Counters are thread-local, so every gated region runs on a **1-thread
//! pool** (the pool runs such jobs inline on the calling thread — nothing
//! escapes the counter, and no other test thread can flake the numbers).
//!
//! What is pinned, honestly:
//! - `adamw_update_mut_scratch` with a warm [`AdamwScratch`] is **strictly
//!   allocation-free** — the PR 4 claim, now machine-checked.
//! - one-token decode (`logits_step_scratch`) with a warm [`DecodeScratch`]
//!   is **strictly allocation-free** for every `AttnKind` — linear variants
//!   by scratch reuse, softmax additionally via the `n_ctx`-reserved KV
//!   cache.
//! - warm chunked prefill (`prefill_chunked_with` on a caller-held
//!   [`PrefillScratch`]) allocates **O(chunk count)** — the carry kernel's
//!   chunk-state table and constant-size tile scratch — never O(prompt
//!   tokens): equal chunk counts at 3× the tokens measure equal.
//! - `train_step_mut` cannot be literally zero-alloc (the forward/backward
//!   activations are per-step temporaries by design), so it is pinned to
//!   **net-zero retained bytes** and a **constant per-step allocation
//!   count** — any leak or accidental per-step growth moves one of the two.
//! - the engine's warm slot-recycling cycle (staging prefill →
//!   `adopt_seq` → masked decode → `clear_seq` → immediate re-admit) is
//!   **strictly allocation-free** — continuous batching adds no
//!   steady-state allocation on top of the decode step it schedules.

#![cfg(feature = "alloc-gate")]

use repro::infer::DecodeState;
use repro::native::model::{self, AdamwScratch, AttnKind, DecodeScratch, LmConfig, Precision, PrefillScratch};
use repro::native::pool::ThreadPool;
use repro::runtime::Tensor;
use repro::util::alloc_gate::measure;
use repro::{alloc_budget, assert_no_alloc};

fn cycle_tokens(cfg: &LmConfig) -> Tensor {
    let n = cfg.batch * (cfg.n_ctx + 1);
    Tensor::i32(vec![cfg.batch, cfg.n_ctx + 1], (0..n).map(|i| (i % 23) as i32).collect()).unwrap()
}

/// Synthetic non-constant gradients matching the config's parameter shapes.
fn grads(cfg: &LmConfig) -> Vec<Vec<f32>> {
    cfg.param_shapes()
        .iter()
        .map(|(_, s)| {
            (0..s.iter().product::<usize>()).map(|j| ((j % 13) as f32 - 6.0) * 1e-3).collect()
        })
        .collect()
}

#[test]
fn adamw_update_mut_scratch_is_allocation_free_when_warm() {
    let cfg = LmConfig::tiny(AttnKind::Ours);
    let mut state = cfg.init_state(0);
    let g = grads(&cfg);
    let pool = ThreadPool::new(1);
    let mut sc = AdamwScratch::new();
    // warm-up: fills the decay flags and the pointer-list capacity
    model::adamw_update_mut_scratch(&cfg, &mut state, &g, 0, &pool, &mut sc).unwrap();

    for step in 1..4 {
        let norm = assert_no_alloc!("adamw_update_mut_scratch (warm)", {
            model::adamw_update_mut_scratch(&cfg, &mut state, &g, step, &pool, &mut sc).unwrap()
        });
        assert!(norm.is_finite() && norm > 0.0);
    }
}

#[test]
fn decode_step_is_allocation_free_when_warm_for_every_attn_kind() {
    for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
        let cfg = LmConfig::tiny(attn);
        let mut state = cfg.init_state(1);
        state.truncate(cfg.n_param_arrays());
        let params: Vec<&Tensor> = state.iter().collect();
        let pool = ThreadPool::new(1);
        let bound = model::DecodeModel::bind(&cfg, &params).unwrap();
        let mut st = DecodeState::new(&cfg, 2).unwrap();
        let mut sc = DecodeScratch::new();
        // warm-up token: grows every scratch buffer to its steady size
        bound.logits_step_scratch(&[1, 2], &mut st, &pool, &mut sc).unwrap();

        for t in 0..4 {
            let tok = [(3 + t) as i32, (5 + t) as i32];
            // the satellite contract: a warm per-token decode step performs
            // ZERO allocation events — the budget is exactly zero, and
            // `alloc_budget!` here is the gate new decode code must pass
            // (the logits view borrows the scratch, so check it in place)
            let finite = alloc_budget!(format!("logits_step_scratch (warm, {attn:?})"), max_allocs = 0, {
                let logits = bound.logits_step_scratch(&tok, &mut st, &pool, &mut sc).unwrap();
                logits.len() == 2 * cfg.vocab && logits.iter().all(|x| x.is_finite())
            });
            assert!(finite, "bad logits from the gated step ({attn:?})");
        }

        // prefill (the logits-free fast path) must be gated too
        assert_no_alloc!(format!("prefill_step_scratch (warm, {attn:?})"), {
            bound.prefill_step_scratch(&[1, 1], &mut st, &pool, &mut sc).unwrap()
        });
    }
}

#[test]
fn warm_chunked_prefill_allocates_per_chunk_not_per_token() {
    // the chunked-prefill satellite contract: with a warm caller-held
    // [`PrefillScratch`], the only allocations left in a whole-prompt pass
    // are the carry kernel's per-window chunk-state table (1 per layer),
    // its per-(seq·head) decay staging (gated only), and the constant-size
    // per-(seq·head, chunk) quadratic tile scratch — all O(chunk count),
    // never O(prompt tokens). Softmax prefill is fully scratch-resident
    // (zero), which the same budget trivially admits.
    for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
        let cfg = LmConfig::tiny(attn);
        let mut state = cfg.init_state(4);
        state.truncate(cfg.n_param_arrays());
        let params: Vec<&Tensor> = state.iter().collect();
        let pool = ThreadPool::new(1);
        let bound = model::DecodeModel::bind(&cfg, &params).unwrap();
        let mut psc = PrefillScratch::new();
        let bh = 2 * cfg.n_head; // 2 sequences

        // warm, 48-token prompt in 16-token chunks (nc = 3)
        let (l, chunk) = (48usize, 16usize);
        let nc = l.div_ceil(chunk);
        let toks: Vec<i32> = (0..2 * l).map(|i| (i % 23) as i32).collect();
        let mut st = DecodeState::new(&cfg, 2).unwrap();
        // warm-up pass grows every scratch buffer to its steady size
        bound.prefill_chunked_with(chunk, &toks, &mut st, &pool, &mut psc).unwrap();
        let budget = cfg.n_layer * (1 + bh + bh * nc) + 4;
        let mut st = DecodeState::new(&cfg, 2).unwrap();
        alloc_budget!(format!("prefill_chunked (warm, {attn:?})"), max_allocs = budget, {
            bound.prefill_chunked_with(chunk, &toks, &mut st, &pool, &mut psc).unwrap()
        });
        // the prefilled window decodes on without further allocation
        let mut sc = DecodeScratch::new();
        bound.logits_step_scratch(&[1, 2], &mut st, &pool, &mut sc).unwrap();

        // chunk-count invariance: one 16-token chunk and one 48-token chunk
        // are both nc = 1 — identical warm allocation counts at 3× the
        // tokens, and nothing retained after either pass
        let mut run_allocs = |l: usize, chunk: usize| -> usize {
            let toks: Vec<i32> = (0..2 * l).map(|i| (i % 23) as i32).collect();
            let mut st = DecodeState::new(&cfg, 2).unwrap();
            // warm-up sizes the scratch for this (l, chunk) shape
            bound.prefill_chunked_with(chunk, &toks, &mut st, &pool, &mut psc).unwrap();
            let mut st = DecodeState::new(&cfg, 2).unwrap();
            let ((), d) = measure(|| {
                bound.prefill_chunked_with(chunk, &toks, &mut st, &pool, &mut psc).unwrap()
            });
            assert_eq!(d.net_bytes(), 0, "{attn:?} l={l}: prefill retained bytes: {d:?}");
            d.allocs
        };
        let short = run_allocs(16, 16);
        let long = run_allocs(48, 48);
        assert_eq!(
            short, long,
            "{attn:?}: warm prefill allocations must track chunk count, not prompt length"
        );
    }
}

#[test]
fn quantized_decode_step_is_allocation_free_when_warm() {
    // the low-precision satellite contract: a warm decode step through
    // bf16/int8 weights AND bf16/int8 recurrent state (dequantize → f32
    // scan → requantize, all in the `sdeq` scratch window) performs the
    // same ZERO allocation events as the f32 path
    for prec in [Precision::Bf16, Precision::Int8] {
        for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
            let cfg = LmConfig::tiny(attn);
            let mut state = cfg.init_state(7);
            state.truncate(cfg.n_param_arrays());
            let params: Vec<&Tensor> = state.iter().collect();
            let pool = ThreadPool::new(1);
            let qm = model::QuantModel::from_params(&cfg, &params, prec).unwrap();
            let bound = model::DecodeModel::bind_quantized(&qm).unwrap();
            let mut st = DecodeState::new(qm.cfg(), 2).unwrap();
            let mut sc = DecodeScratch::new();
            // warm-up token: grows every scratch buffer (incl. `sdeq`)
            bound.logits_step_scratch(&[1, 2], &mut st, &pool, &mut sc).unwrap();

            for t in 0..4 {
                let tok = [(3 + t) as i32, (5 + t) as i32];
                let finite = alloc_budget!(
                    format!("logits_step_scratch (warm, {attn:?}, {prec})"),
                    max_allocs = 0,
                    {
                        let logits =
                            bound.logits_step_scratch(&tok, &mut st, &pool, &mut sc).unwrap();
                        logits.len() == 2 * cfg.vocab && logits.iter().all(|x| x.is_finite())
                    }
                );
                assert!(finite, "bad logits from the gated quantized step ({attn:?}, {prec})");
            }
        }
    }
}

#[test]
fn train_step_mut_retains_nothing_and_has_constant_alloc_count() {
    let cfg = LmConfig::tiny(AttnKind::Ours);
    let mut state = cfg.init_state(2);
    let tokens = cycle_tokens(&cfg);
    let pool = ThreadPool::new(1);
    // warm-up: first step pays one-time lazy init (pool state, tensors)
    model::train_step_mut(&cfg, &mut state, &tokens, 0, &pool).unwrap();

    let (_, d1) = measure(|| model::train_step_mut(&cfg, &mut state, &tokens, 1, &pool).unwrap());
    let (_, d2) = measure(|| model::train_step_mut(&cfg, &mut state, &tokens, 2, &pool).unwrap());

    // every forward/backward temporary must be returned to the allocator —
    // a warm in-place step retains zero bytes
    assert_eq!(d1.net_bytes(), 0, "step 1 retained bytes: {d1:?}");
    assert_eq!(d2.net_bytes(), 0, "step 2 retained bytes: {d2:?}");
    // and the per-step allocation count is flat: any accidental
    // per-step growth (caching, logging, leaked scratch) breaks equality
    assert_eq!(d1.allocs, d2.allocs, "alloc count drifted: {d1:?} vs {d2:?}");
    assert!(d1.allocs > 0, "a train step legitimately allocates activations");
}

#[test]
fn softmax_kv_cache_reservation_survives_a_full_window() {
    // decode a full context window: with the up-front KV reservation the
    // softmax cache must never reallocate, so *every* warm token is free
    let cfg = LmConfig::tiny(AttnKind::Softmax);
    let mut state = cfg.init_state(3);
    state.truncate(cfg.n_param_arrays());
    let params: Vec<&Tensor> = state.iter().collect();
    let pool = ThreadPool::new(1);
    let bound = model::DecodeModel::bind(&cfg, &params).unwrap();
    let mut st = DecodeState::new(&cfg, 1).unwrap();
    let mut sc = DecodeScratch::new();
    bound.logits_step_scratch(&[0], &mut st, &pool, &mut sc).unwrap();

    let ((), d) = measure(|| {
        for t in 1..cfg.n_ctx {
            bound.logits_step_scratch(&[(t % cfg.vocab) as i32], &mut st, &pool, &mut sc).unwrap();
        }
    });
    assert_eq!(
        d.allocs, 0,
        "softmax decode allocated across a full window (KV reservation lost?): {d:?}"
    );
}

#[test]
fn slot_recycling_admit_decode_evict_admit_is_allocation_free_when_warm() {
    // the continuous-batching engine's steady state: a request prefills
    // through the one-sequence staging state, is adopted into a free batch
    // slot, decodes under the active mask, is evicted with `clear_seq`,
    // and the freed slot immediately hosts the next admission — all on
    // buffers sized at engine construction. With every scratch warm, one
    // full recycle performs ZERO allocation events, for every mixer.
    for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
        let cfg = LmConfig::tiny(attn);
        let mut state = cfg.init_state(6);
        state.truncate(cfg.n_param_arrays());
        let params: Vec<&Tensor> = state.iter().collect();
        let pool = ThreadPool::new(1);
        let bound = model::DecodeModel::bind(&cfg, &params).unwrap();
        let mut batch = DecodeState::new(&cfg, 2).unwrap();
        let mut staging = DecodeState::new(&cfg, 1).unwrap();
        let mut sc = DecodeScratch::new();
        let mut ssc = DecodeScratch::new();
        // slot 0 plays the parked resident the engine schedules around
        bound.prefill_step_scratch(&[7, 7], &mut batch, &pool, &mut sc).unwrap();

        let mut recycle = |seed_tok: i32| {
            staging.reset();
            for t in 0..3 {
                bound
                    .prefill_step_scratch(&[seed_tok + t], &mut staging, &pool, &mut ssc)
                    .unwrap();
            }
            batch.adopt_seq(1, &staging).unwrap();
            let active = [false, true];
            let mut tok = [0i32, seed_tok];
            for step in 0..3 {
                let logits =
                    bound.decode_step_masked(&tok, &active, &mut batch, &pool, &mut sc).unwrap();
                assert!(
                    logits.iter().all(|x| x.is_finite()),
                    "bad logits from the recycled slot ({attn:?})"
                );
                tok[1] = (seed_tok + step) % 23;
            }
            batch.clear_seq(1).unwrap();
            // re-admit into the just-freed slot
            staging.reset();
            bound.prefill_step_scratch(&[seed_tok], &mut staging, &pool, &mut ssc).unwrap();
            batch.adopt_seq(1, &staging).unwrap();
            bound.decode_step_masked(&tok, &active, &mut batch, &pool, &mut sc).unwrap();
            batch.clear_seq(1).unwrap();
        };
        recycle(1); // warm-up: grows every scratch to its steady size
        assert_no_alloc!(format!("slot recycle admit→decode→evict→admit (warm, {attn:?})"), {
            recycle(2)
        });
    }
}
