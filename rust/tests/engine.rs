//! Continuous-batching engine integration tests: batch-vs-solo token parity
//! per `AttnKind` (joining a busy batch mid-stream must not change a
//! request's tokens), bounded-queue load shedding, join/leave schedule
//! determinism under a fixed seed, EOF draining through the serve loop, and
//! the loadgen smoke the CI lane mirrors.

// Too slow under the Miri interpreter (tests/miri_parity.rs covers the
// unsafe families at reduced sizes instead).
#![cfg(not(miri))]

use std::collections::HashMap;
use std::io::Cursor;

use repro::coordinator::{Checkpoint, CheckpointMeta, PARAM_LAYOUT_VERSION};
use repro::data::ByteTokenizer;
use repro::infer::engine::loadgen;
use repro::infer::{
    serve_loop, BatchEngine, EngineConfig, EngineOutput, EngineResponse, GenRequest,
    LoadGenConfig, ModelSession, SampleMode,
};
use repro::native::model::{self, AttnKind, LmConfig};
use repro::native::pool::ThreadPool;
use repro::runtime::Tensor;
use repro::simulator::ArrivalPattern;
use repro::util::json::Json;

/// Everything a checkpoint-free engine borrows, bundled so tests can build
/// several engines over the same weights.
struct Parts {
    cfg: LmConfig,
    params: Vec<Tensor>,
    tokenizer: ByteTokenizer,
    pool: ThreadPool,
}

fn parts(attn: AttnKind, seed: u64) -> Parts {
    let cfg = LmConfig::tiny(attn);
    let mut params = cfg.init_state(seed);
    params.truncate(cfg.n_param_arrays());
    let tokenizer = ByteTokenizer::for_artifact(cfg.vocab, 0).unwrap();
    let pool = ThreadPool::new(2);
    Parts { cfg, params, tokenizer, pool }
}

impl Parts {
    fn engine(&self, conf: EngineConfig) -> BatchEngine<'_> {
        let refs: Vec<&Tensor> = self.params.iter().collect();
        let bound = model::DecodeModel::bind(&self.cfg, &refs).unwrap();
        BatchEngine::new(bound, &self.tokenizer, &self.pool, conf).unwrap()
    }
}

fn greedy(prompt: &str, max_new: usize) -> GenRequest {
    GenRequest {
        prompt: prompt.to_string(),
        max_new,
        mode: SampleMode::Greedy,
        seed: 0,
        samples: 1,
        ..GenRequest::default()
    }
}

/// Completed outputs keyed by serial; panics on any failed response.
fn outputs_of(resps: Vec<EngineResponse>) -> HashMap<u64, EngineOutput> {
    resps
        .into_iter()
        .map(|r| {
            let serial = r.serial;
            (serial, r.result.unwrap_or_else(|e| panic!("request {serial} failed: {e:#}")))
        })
        .collect()
}

/// A request's sampled tokens must be bit-identical whether it decodes in
/// an otherwise empty engine or joins a batch whose neighbour is already
/// mid-stream — the row-independence contract of the masked decode step,
/// per mixer family. The probe samples (top-k, fixed seed) so the parity
/// also covers the per-request RNG stream, not just the argmax.
#[test]
fn joining_a_busy_batch_leaves_tokens_bit_identical() {
    for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
        let p = parts(attn, 21);
        let probe = GenRequest {
            prompt: "the quick brown ".to_string(),
            max_new: 10,
            mode: SampleMode::TopK { k: 8, temperature: 1.0 },
            seed: 77,
            samples: 1,
            ..GenRequest::default()
        };

        // solo: the probe has the whole engine to itself
        let mut solo = p.engine(EngineConfig::default());
        solo.submit(0, probe.clone());
        solo.drain().unwrap();
        let solo_out = outputs_of(solo.take_finished()).remove(&0).unwrap();
        assert_eq!(solo_out.new_tokens, 10, "{attn:?}");

        // busy: a long-running neighbour is several tokens in when the
        // probe joins, and it keeps decoding after the probe leaves
        let mut busy = p.engine(EngineConfig::default());
        busy.submit(0, greedy("a much longer neighbouring prompt ", 24));
        for _ in 0..4 {
            busy.step().unwrap();
        }
        assert_eq!(busy.occupancy(), 1, "{attn:?}: neighbour not yet decoding");
        busy.submit(1, probe.clone());
        busy.drain().unwrap();
        assert!(busy.is_idle());
        assert!(
            busy.stats().max_occupancy >= 2,
            "{attn:?}: probe never overlapped the neighbour"
        );
        let m = outputs_of(busy.take_finished());
        let joined = &m[&1];
        assert_eq!(
            joined.token_ids, solo_out.token_ids,
            "{attn:?}: joining a busy batch changed the probe's tokens"
        );
        assert_eq!(joined.texts, solo_out.texts, "{attn:?}: decoded text diverged");
        assert!(joined.occupancy_mean > 1.0, "{attn:?}: probe decoded unbatched");
    }
}

/// The bounded admission queue sheds overflow with an explicit `queue_full`
/// rejection (flagged `rejected`, distinct from a validation error), and an
/// over-wide `samples` answers an error — neither aborts or starves the
/// warm engine.
#[test]
fn queue_overflow_sheds_and_absurd_samples_answer_errors() {
    let p = parts(AttnKind::Ours, 3);
    let mut e = p.engine(EngineConfig { slots: 1, queue: 2, prefill_budget: 64 });
    for serial in 0..4u64 {
        e.submit(serial, greedy("the ", 3));
    }
    e.submit(9, GenRequest { samples: 5, ..greedy("the ", 2) });
    let early = e.take_finished();
    assert_eq!(early.len(), 3);
    for r in &early {
        match r.serial {
            2 | 3 => {
                assert!(r.rejected, "overflow must be flagged as shed");
                let err = format!("{:#}", r.result.as_ref().unwrap_err());
                assert!(err.contains("queue_full"), "unhelpful rejection: {err}");
            }
            9 => {
                assert!(!r.rejected, "a validation error is not load shedding");
                let err = format!("{:#}", r.result.as_ref().unwrap_err());
                assert!(err.contains("slot"), "unhelpful samples error: {err}");
            }
            other => panic!("unexpected early response for serial {other}"),
        }
    }

    // the two admitted requests still complete
    e.drain().unwrap();
    let done = outputs_of(e.take_finished());
    assert_eq!(done.len(), 2);
    assert!(done.contains_key(&0) && done.contains_key(&1));
    assert_eq!(e.stats().rejected, 2);
    assert_eq!(e.stats().errors, 1);
    assert_eq!(e.stats().completed, 2);
    assert_eq!(e.stats().submitted, 5);
}

/// The same staggered submit/step schedule run twice must produce identical
/// tokens for every request: admission order, slot assignment, and each
/// request's sampler stream are all functions of the inputs, never of
/// wall-clock timing.
#[test]
fn join_leave_schedule_is_deterministic_under_a_fixed_seed() {
    let p = parts(AttnKind::Ours, 5);
    let run = || {
        let mut e = p.engine(EngineConfig { slots: 3, queue: 8, prefill_budget: 32 });
        e.submit(
            0,
            GenRequest {
                prompt: "alpha ".to_string(),
                max_new: 9,
                mode: SampleMode::TopK { k: 8, temperature: 0.9 },
                seed: 11,
                samples: 1,
                ..GenRequest::default()
            },
        );
        e.step().unwrap();
        e.step().unwrap();
        e.submit(
            1,
            GenRequest {
                prompt: "beta ".to_string(),
                max_new: 5,
                mode: SampleMode::TopK { k: 4, temperature: 1.1 },
                seed: 22,
                samples: 2,
                ..GenRequest::default()
            },
        );
        e.step().unwrap();
        e.submit(2, greedy("gamma ", 7));
        e.drain().unwrap();
        assert!(e.stats().max_occupancy > 1, "schedule never overlapped");
        let m = outputs_of(e.take_finished());
        m.into_iter().map(|(s, o)| (s, o.token_ids)).collect::<HashMap<_, _>>()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "replaying the schedule changed the tokens");
    assert_eq!(a.len(), 3);
    assert_eq!(a[&1].len(), 2, "two samples decode two token streams");
    assert_eq!(a[&1][0].len(), 5);
}

/// An idle engine's step is a no-op `Ok(false)`, and `drain` leaves the
/// engine idle with every submission answered.
#[test]
fn drain_answers_everything_and_idles_the_engine() {
    let p = parts(AttnKind::Gated, 7);
    let mut e = p.engine(EngineConfig { slots: 2, queue: 8, prefill_budget: 16 });
    assert!(!e.step().unwrap(), "an idle engine must report no progress");
    for serial in 0..5u64 {
        e.submit(serial, greedy("some prompt text ", 4));
    }
    e.drain().unwrap();
    assert!(e.is_idle());
    assert_eq!(e.occupancy(), 0);
    let done = outputs_of(e.take_finished());
    assert_eq!(done.len(), 5);
    for out in done.values() {
        assert_eq!(out.new_tokens, 4);
        assert!(out.ttft_s.is_finite() && out.ttft_s >= 0.0);
    }
    assert!(!e.step().unwrap(), "a drained engine must be idle again");
}

fn write_ckpt(dir: &std::path::Path, name: &str, cfg: &LmConfig) {
    let meta = CheckpointMeta {
        artifact_tag: "lm_tiny_ours".to_string(),
        step: 1,
        loss: 1.5,
        seed: 0,
        layout: PARAM_LAYOUT_VERSION,
    };
    Checkpoint::write(dir.join(name), &meta, &cfg.init_state(0)).unwrap();
}

/// The serve loop over the engine: overlapping requests (a long first
/// request, short followers that may well finish before it) must come back
/// ok, in strict submission order, with the engine-era latency fields —
/// and EOF must drain the in-flight long request cleanly.
#[test]
fn serve_loop_preserves_submission_order_and_drains_on_eof() {
    let dir = std::env::temp_dir().join("repro_engine_serve");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = LmConfig::tiny(AttnKind::Ours);
    write_ckpt(&dir, "ok.ckpt", &cfg);
    let session = ModelSession::load(dir.join("ok.ckpt")).unwrap();

    let input = concat!(
        "{\"id\": 1, \"prompt\": \"the long one \", \"max_new\": 24}\n",
        "{\"id\": 2, \"prompt\": \"a \", \"max_new\": 2}\n",
        "{\"id\": 3, \"prompt\": \"b \", \"max_new\": 2}\n",
        "{\"id\": 4, \"prompt\": \"c \", \"max_new\": 2}\n",
        "{\"id\": 5, \"prompt\": \"d \", \"max_new\": 2}\n",
        "{\"id\": 6, \"prompt\": \"e \", \"max_new\": 2}\n",
    );
    let mut out = Vec::new();
    let stats = serve_loop(&session, Cursor::new(input), &mut out, 64).unwrap();
    assert_eq!(stats.requests, 6);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.engine.completed, 6);

    let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().lines().collect();
    assert_eq!(lines.len(), 6);
    for (i, line) in lines.iter().enumerate() {
        let r = Json::parse(line).unwrap();
        assert_eq!(
            r.get("id").and_then(Json::as_usize),
            Some(i + 1),
            "responses must come back in submission order"
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        assert!(r.get("ttft_ms").and_then(Json::as_f64).is_some());
        assert!(r.get("queue_ms").and_then(Json::as_f64).is_some());
        assert!(r.get("decode_tok_s").and_then(Json::as_f64).is_some());
        assert!(r.get("occupancy_mean").and_then(Json::as_f64).is_some());
    }
    let r1 = Json::parse(lines[0]).unwrap();
    assert_eq!(r1.get("new_tokens").and_then(Json::as_usize), Some(24));
}

/// The in-process load generator (the CI smoke in test form): 8 requests in
/// staggered bursts of 4 over 4 slots must all complete, with finite TTFT
/// percentiles for every request, genuine batching (max occupancy above 1),
/// and a traffic-model fit over the run's step samples.
#[test]
fn loadgen_burst_overlaps_and_answers_every_request() {
    let p = parts(AttnKind::Ours, 9);
    let mut e = p.engine(EngineConfig::default());
    let conf = LoadGenConfig {
        n_requests: 8,
        pattern: ArrivalPattern::Burst { burst: 4, gap_s: 0.02 },
        seed: 0,
        prompt_len: 16,
        max_new: 8,
        cycles_per_s: 200.0,
    };
    let report = loadgen::run(&mut e, &conf).unwrap();
    assert_eq!(report.submitted, 8);
    assert_eq!(report.completed, 8);
    assert_eq!(report.rejected, 0);
    assert_eq!(report.errors, 0);
    assert!(report.stats.max_occupancy > 1, "burst load never overlapped in the batch");
    let ttft = report.stats.ttft_stats().unwrap();
    assert_eq!(ttft.reps, 8);
    assert_eq!(ttft.dropped, 0, "a non-finite TTFT slipped through");
    assert!(ttft.p50 >= 0.0 && ttft.p99 >= ttft.p50);
    assert!(report.fit.is_some(), "enough step samples for a fit");
    let summary = report.summary();
    assert!(summary.contains("8 submitted, 8 completed"), "summary:\n{summary}");
    assert!(summary.contains("fit:"), "summary missing the fit line:\n{summary}");
}
