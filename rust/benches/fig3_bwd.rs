//! Fig 3: backward-pass (fwd+bwd vjp) time & memory scaling vs N and D.
//! "Ours" exercises the analytical-gradient kernels (Eq. 16-21); baselines
//! autodiff through their forward graphs, reproducing the O(N·D²)-residency
//! trap the paper describes for causal LA under autodiff.

mod common;

use repro::bench::report::{sweep_csv, sweep_markdown};
use repro::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::discover()?;
    let reps = if common::quick_mode() { 2 } else { 3 };
    let runner = common::runner(&engine, reps);

    let mut points = Vec::new();
    for impl_name in ["ours", "ours_scan", "gated", "quadratic", "specdec", "flash", "softmax"] {
        // backward is ~3× forward cost: halve the caps
        let cap = match impl_name {
            "ours_scan" | "gated" => usize::MAX,
            other => common::time_cap(other).saturating_div(2).max(2048),
        };
        for (name, meta) in engine.manifest.layer_sweep("layer_fwdbwd", impl_name) {
            if meta.n.unwrap_or(0) > cap || !runner.fits(name) {
                continue;
            }
            eprintln!("fig3: {name}");
            points.push(runner.run_artifact(name)?);
        }
    }
    println!("{}", sweep_markdown("Fig 3 — forward+backward pass", &points));
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/fig3_bwd.csv", sweep_csv(&points))?;
    eprintln!("wrote bench_out/fig3_bwd.csv");
    Ok(())
}
