//! Fig 2: forward-pass time & memory scaling vs N (D=128) and vs D (N=4096),
//! for every implementation — measured on CPU PJRT, with the analytic A6000
//! model series alongside.

mod common;

use repro::bench::report::{sweep_csv, sweep_markdown};
use repro::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::discover()?;
    let reps = if common::quick_mode() { 2 } else { 3 };
    let runner = common::runner(&engine, reps);

    let mut points = Vec::new();
    for impl_name in ["ours", "ours_scan", "gated", "quadratic", "specdec", "flash", "softmax"] {
        let cap = common::time_cap(impl_name);
        for (name, meta) in engine.manifest.layer_sweep("layer_fwd", impl_name) {
            if meta.n.unwrap_or(0) > cap || !runner.fits(name) {
                continue;
            }
            eprintln!("fig2: {name}");
            points.push(runner.run_artifact(name)?);
        }
    }
    println!("{}", sweep_markdown("Fig 2 — forward pass", &points));
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/fig2_fwd.csv", sweep_csv(&points))?;
    eprintln!("wrote bench_out/fig2_fwd.csv");
    Ok(())
}
