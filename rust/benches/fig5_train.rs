//! Fig 5 (bench form): per-step training wall-clock for each attention
//! implementation on the tiny LM — the end-to-end speedup comparison.
//! (The full learning curves come from `examples/train_lm.rs`.)

mod common;

use std::time::Instant;

use repro::coordinator::config::{DataSection, OutputSection, TrainSection};
use repro::coordinator::{RunConfig, Trainer};
use repro::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::discover()?;
    let steps = if common::quick_mode() { 4 } else { 10 };
    println!("| attn | preset | step p50 | tok/s |");
    println!("|---|---|---|---|");
    for attn in ["ours", "gated", "softmax"] {
        let cfg = RunConfig {
            train: TrainSection {
                preset: "tiny".into(),
                attn: attn.into(),
                steps,
                eval_every: 0,
                ckpt_every: 0,
                seed: 0,
            },
            data: DataSection { corpus_bytes: 1 << 20, val_frac: 0.05 },
            output: OutputSection { dir: "bench_out/fig5_runs".into() },
        };
        let trainer = Trainer::new(&engine, cfg)?;
        let (_tok, ds) = trainer.build_dataset()?;
        let mut batcher = repro::data::Batcher::new(
            &ds,
            repro::data::Split::Train,
            trainer.batch_size(),
            0,
        )?;
        let mut state = trainer.init_state()?;
        let mut times = Vec::new();
        for step in 0..steps {
            let batch = batcher.next_batch()?;
            let t0 = Instant::now();
            let (_loss, new_state) = trainer.step(state, &batch, step)?;
            times.push(t0.elapsed().as_secs_f64());
            state = new_state;
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = times[times.len() / 2];
        let tokens = trainer.batch_size() * (trainer.seq_len() + 1);
        println!(
            "| {attn} | tiny | {:.1} ms | {:.0} |",
            p50 * 1e3,
            tokens as f64 / p50
        );
    }
    Ok(())
}
