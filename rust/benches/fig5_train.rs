//! Fig 5 (bench form): per-step training wall-clock and loss movement for
//! each attention implementation on both LM presets — the end-to-end
//! comparison on the shallow (tiny) and deep (small) models, via the shared
//! [`repro::bench::lm`] measurement helper and table emitter.
//! (The full learning curves come from `examples/train_lm.rs`.)

mod common;

use repro::bench::lm::{build_preset_dataset, measure_lm};
use repro::bench::report::bench_lm_markdown;
use repro::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::discover()?;
    let mut points = Vec::new();
    for preset in ["tiny", "small"] {
        // the deep preset costs ~10× per step — fewer steps keep the bench bounded
        let steps = match (preset, common::quick_mode()) {
            ("tiny", true) => 4,
            ("tiny", false) => 10,
            (_, true) => 3,
            (_, false) => 6,
        };
        let ds = build_preset_dataset(&engine, preset)?;
        for attn in ["ours", "gated", "softmax"] {
            eprintln!("fig5: {preset}/{attn} ({steps} steps) …");
            points.push(measure_lm(&engine, preset, attn, steps, &ds)?);
        }
    }
    println!("{}", bench_lm_markdown(&points));
    Ok(())
}
