//! Shared helpers for the bench binaries (plain `harness = false` mains —
//! criterion is unavailable in this offline environment, so each bench is a
//! small self-contained harness printing the paper's rows/series).

use repro::bench::SweepRunner;
use repro::runtime::Engine;

/// Parse `--quick` style flags from the bench argv (cargo bench passes
/// `--bench`; ignore it).
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
}

/// Engine + runner tuned for benching.
pub fn runner(engine: &Engine, reps: usize) -> SweepRunner<'_> {
    let mut r = SweepRunner::new(engine);
    r.reps = reps;
    r.warmup = 1;
    r
}

/// Cap on N for quadratic-*time* implementations so a single-core bench run
/// stays bounded (memory caps are enforced by the artifact set itself).
pub const QUAD_TIME_N_CAP: usize = 4096;
pub const FLASH_TIME_N_CAP: usize = 8192;
/// Interpret-mode Pallas pays a large per-grid-step dispatch cost on CPU
/// (structural path, not a perf proxy — DESIGN.md); `ours_scan` carries the
/// full-range wall-clock series for the same algorithm.
pub const INTERPRET_TIME_N_CAP: usize = 8192;

pub fn time_cap(impl_name: &str) -> usize {
    match impl_name {
        "quadratic" | "specdec" | "softmax" => QUAD_TIME_N_CAP,
        "flash" => FLASH_TIME_N_CAP,
        "ours" => INTERPRET_TIME_N_CAP,
        _ => usize::MAX,
    }
}
