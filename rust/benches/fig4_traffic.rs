//! Fig 4: ratio of data-movement time to total runtime (left panel) and
//! total data-movement time (right panel) for the LA implementations —
//! fully analytic (DESIGN.md §Substitutions documents why), plus the Pallas
//! VMEM/MXU §Hardware-Adaptation estimates.

use repro::bench::report::{fig4_csv, fig4_markdown, fmt_bytes};
use repro::simulator::{DeviceSpec, TrafficModel, VmemModel};

fn main() -> anyhow::Result<()> {
    let model = TrafficModel::new(DeviceSpec::a6000());
    let ns = [2048, 4096, 8192, 16384, 32768];
    println!("## Fig 4 — data movement (analytic A6000, BH=64 D=128)\n");
    println!("{}", fig4_markdown(&model, &ns));

    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/fig4_traffic.csv", fig4_csv(&model, &ns))?;
    eprintln!("wrote bench_out/fig4_traffic.csv");

    println!("\n## Pallas kernel on-chip model (TPU §Hardware-Adaptation)\n");
    println!("| C | D | fwd VMEM | bwd VMEM | 16MiB occupancy | MXU util |");
    println!("|---|---|---|---|---|---|");
    for (c, d) in [(64, 64), (128, 128), (128, 256), (128, 512)] {
        let vm = VmemModel::new(c, d);
        println!(
            "| {c} | {d} | {} | {} | {:.1}% | {:.0}% |",
            fmt_bytes(vm.forward_bytes() as f64),
            fmt_bytes(vm.backward_bytes() as f64),
            vm.forward_occupancy(16 << 20) * 100.0,
            vm.mxu_utilization() * 100.0
        );
    }
    Ok(())
}
