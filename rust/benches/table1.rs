//! Table 1: complexity/latency summary.
//!
//! Analytic columns reproduce the paper's exact point (B=4, H=16, D=128,
//! N=10⁴ on an A6000); the measured column runs the same algorithms through
//! the CPU-PJRT runtime at the largest host-feasible shape so the *ordering*
//! is validated by real execution.

mod common;

use repro::bench::report::{fmt_bytes, fmt_time, table1_markdown};
use repro::runtime::Engine;
use repro::simulator::{DeviceSpec, Impl, TrafficModel};

fn main() -> anyhow::Result<()> {
    let model = TrafficModel::new(DeviceSpec::a6000());
    println!("## Table 1 — analytic A6000 model (B=4 H=16 D=128 N=10⁴)\n");
    println!("{}", table1_markdown(&model));

    println!("\n## Table 1 — measured (CPU PJRT, BH=4 D=128, N=4096)\n");
    let engine = Engine::discover()?;
    let runner = common::runner(&engine, if common::quick_mode() { 2 } else { 5 });
    println!("| impl | N | fwd p50 (CPU) | model fwd (A6000) | model memory |");
    println!("|---|---|---|---|---|");
    for impl_name in ["softmax", "flash", "specdec", "gated", "ours"] {
        let n = 4096usize;
        let name = format!("layer_{impl_name}_fwd_n{n}_d128");
        if engine.manifest.get(&name).is_err() {
            continue;
        }
        let p = runner.run_artifact(&name)?;
        let imp = Impl::from_name(impl_name).unwrap();
        let rep = model.report(imp, 64, 10_000, 128);
        println!(
            "| {impl_name} | {n} | {} | {} | {} |",
            fmt_time(p.cpu_s.p50),
            fmt_time(rep.total_s),
            fmt_bytes(rep.mem_bytes),
        );
    }
    Ok(())
}
